//! Color container (`CDC3`): a color header followed by three complete
//! `CDC1` plane streams in Y/Cb/Cr order.
//!
//! ```text
//! magic "CDC3" | width | height | quality | variant | subsampling |
//! 3 x ( u32 stream length | CDC1 plane stream )
//! ```
//!
//! Each plane stream is exactly what [`super::encoder::encode`] emits for
//! a grayscale image — its own header (plane dimensions) and its own
//! per-plane Huffman tables — so the color decoder is three calls into
//! the existing grayscale decoder plus consistency checks. Chroma planes
//! carry their subsampled dimensions; the color header's `subsampling`
//! tag tells the decoder how to upsample.
//!
//! The v2 front doors ([`encode_v2`], [`encode_scanned_v2`]) keep the
//! same `CDC3` wrapper but embed `CDC2` restart-segment plane streams,
//! and [`decode_salvage`] tolerates damage: per-plane salvage decoding,
//! magic-scan recovery when a plane length field is corrupted, and
//! whole-plane concealment (mid-gray) when a plane head is unusable.

use anyhow::{bail, Context, Result};

use crate::dct::color::PlaneCoef;
use crate::image::ycbcr::Subsampling;

use super::encoder::ScanCoefs;
use super::{decode_bail, decoder, encoder, DecodeErrorKind, Header};
use super::{PlaneSalvage, SalvageReport};
use super::{MAGIC, MAGIC_V2, MAX_DIM, MAX_PIXELS};

/// Validate plane dimensions against the container geometry.
fn check_plane_dims(
    header: &ColorHeader,
    i: usize,
    dims: (usize, usize),
) -> Result<()> {
    let sub = tag_subsampling(header.subsampling)?;
    let (w, h) = (header.width as usize, header.height as usize);
    let (cw, ch) = sub.chroma_dims(w, h);
    let want = [(w, h), (cw, ch), (cw, ch)];
    if dims != want[i] {
        bail!(
            "plane {i} is {}x{}, expected {}x{} for {} at {w}x{h}",
            dims.0,
            dims.1,
            want[i].0,
            want[i].1,
            sub.as_str()
        );
    }
    Ok(())
}

pub const COLOR_MAGIC: &[u8; 4] = b"CDC3";

/// Subsampling <-> tag mapping for the header byte.
pub fn subsampling_tag(s: Subsampling) -> u8 {
    match s {
        Subsampling::S444 => 0,
        Subsampling::S422 => 1,
        Subsampling::S420 => 2,
    }
}

pub fn tag_subsampling(t: u8) -> Result<Subsampling> {
    Ok(match t {
        0 => Subsampling::S444,
        1 => Subsampling::S422,
        2 => Subsampling::S420,
        _ => bail!("unknown subsampling tag {t}"),
    })
}

/// Compressed color-image container header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ColorHeader {
    /// Original RGB image size.
    pub width: u32,
    pub height: u32,
    /// IJG quality the quantizers used (luma + chroma tables).
    pub quality: u8,
    /// Transform variant tag (shared with the gray container).
    pub variant: u8,
    /// Chroma subsampling tag (see [`subsampling_tag`]).
    pub subsampling: u8,
}

impl ColorHeader {
    pub const BYTES: usize = 4 + 4 * 2 + 3;

    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(COLOR_MAGIC);
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.height.to_le_bytes());
        out.push(self.quality);
        out.push(self.variant);
        out.push(self.subsampling);
    }

    pub fn read(bytes: &[u8]) -> Result<(ColorHeader, usize)> {
        if bytes.len() < Self::BYTES {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "file too short for CDC3 header: {} bytes",
                bytes.len()
            );
        }
        if &bytes[0..4] != COLOR_MAGIC {
            decode_bail!(
                DecodeErrorKind::BadMagic,
                "bad magic: not a CDC3 color file"
            );
        }
        let rd = |o: usize| {
            u32::from_le_bytes([
                bytes[o],
                bytes[o + 1],
                bytes[o + 2],
                bytes[o + 3],
            ])
        };
        let h = ColorHeader {
            width: rd(4),
            height: rd(8),
            quality: bytes[12],
            variant: bytes[13],
            subsampling: bytes[14],
        };
        if h.width > MAX_DIM
            || h.height > MAX_DIM
            || h.width as u64 * h.height as u64 > MAX_PIXELS
        {
            decode_bail!(
                DecodeErrorKind::TooLarge,
                "color image {}x{} exceeds caps",
                h.width,
                h.height
            );
        }
        if h.width == 0 || h.height == 0 {
            decode_bail!(
                DecodeErrorKind::BadHeader,
                "inconsistent CDC3 header {h:?}"
            );
        }
        if tag_subsampling(h.subsampling).is_err() {
            decode_bail!(
                DecodeErrorKind::BadHeader,
                "unknown subsampling tag {}",
                h.subsampling
            );
        }
        Ok((h, Self::BYTES))
    }
}

/// Is this byte stream a color (`CDC3`) container? Used by readers that
/// accept either format.
pub fn is_color_container(bytes: &[u8]) -> bool {
    bytes.len() >= 4 && &bytes[0..4] == COLOR_MAGIC
}

/// Encode three quantized planes (Y/Cb/Cr order, as
/// [`crate::dct::color::ColorPipeline::analyze`] emits them) into one
/// color container.
pub fn encode(
    header: &ColorHeader,
    planes: &[PlaneCoef; 3],
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    header.write(&mut out);
    for (i, plane) in planes.iter().enumerate() {
        check_plane_dims(header, i, (plane.width, plane.height))?;
        let ph = Header {
            width: plane.width as u32,
            height: plane.height as u32,
            padded_width: plane.padded_width as u32,
            padded_height: plane.padded_height as u32,
            quality: header.quality,
            variant: header.variant,
        };
        let stream = encoder::encode(&ph, &plane.qcoef)
            .with_context(|| format!("encoding plane {i}"))?;
        out.extend_from_slice(&(stream.len() as u32).to_le_bytes());
        out.extend_from_slice(&stream);
    }
    Ok(out)
}

/// Encode three planes of already-zigzag-ordered coefficients (the fused
/// `quantize_zigzag_batch` output, as `ColorCompressOutput::scanned`
/// carries them) into one color container. Byte-identical to [`encode`]
/// over the equivalent planar buffers.
pub fn encode_scanned(
    header: &ColorHeader,
    planes: &[ScanCoefs; 3],
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    header.write(&mut out);
    for (i, plane) in planes.iter().enumerate() {
        check_plane_dims(header, i, (plane.width, plane.height))?;
        let ph = Header {
            width: plane.width as u32,
            height: plane.height as u32,
            padded_width: plane.padded_width as u32,
            padded_height: plane.padded_height as u32,
            quality: header.quality,
            variant: header.variant,
        };
        let stream = encoder::encode_scanned(&ph, plane)
            .with_context(|| format!("encoding plane {i}"))?;
        out.extend_from_slice(&(stream.len() as u32).to_le_bytes());
        out.extend_from_slice(&stream);
    }
    Ok(out)
}

/// Like [`encode`], but each plane is a `CDC2` restart-segment stream
/// with the given restart interval (block rows per segment; 0 = one
/// segment per plane).
pub fn encode_v2(
    header: &ColorHeader,
    planes: &[PlaneCoef; 3],
    restart_interval: u16,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    header.write(&mut out);
    for (i, plane) in planes.iter().enumerate() {
        check_plane_dims(header, i, (plane.width, plane.height))?;
        let ph = Header {
            width: plane.width as u32,
            height: plane.height as u32,
            padded_width: plane.padded_width as u32,
            padded_height: plane.padded_height as u32,
            quality: header.quality,
            variant: header.variant,
        };
        let stream =
            encoder::encode_v2(&ph, &plane.qcoef, restart_interval)
                .with_context(|| format!("encoding plane {i}"))?;
        out.extend_from_slice(&(stream.len() as u32).to_le_bytes());
        out.extend_from_slice(&stream);
    }
    Ok(out)
}

/// Like [`encode_scanned`], but each plane is a `CDC2` restart-segment
/// stream. Byte-identical to [`encode_v2`] over equivalent planar
/// buffers.
pub fn encode_scanned_v2(
    header: &ColorHeader,
    planes: &[ScanCoefs; 3],
    restart_interval: u16,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    header.write(&mut out);
    for (i, plane) in planes.iter().enumerate() {
        check_plane_dims(header, i, (plane.width, plane.height))?;
        let ph = Header {
            width: plane.width as u32,
            height: plane.height as u32,
            padded_width: plane.padded_width as u32,
            padded_height: plane.padded_height as u32,
            quality: header.quality,
            variant: header.variant,
        };
        let stream =
            encoder::encode_scanned_v2(&ph, plane, restart_interval)
                .with_context(|| format!("encoding plane {i}"))?;
        out.extend_from_slice(&(stream.len() as u32).to_le_bytes());
        out.extend_from_slice(&stream);
    }
    Ok(out)
}

/// Decoded color container: header + per-plane coefficients.
pub struct ColorDecoded {
    pub header: ColorHeader,
    pub planes: [PlaneCoef; 3],
}

/// Decode a `CDC3` container back to plane coefficients. Strictly
/// validating, like the grayscale decoder: corrupt input errors, never
/// panics.
pub fn decode(bytes: &[u8]) -> Result<ColorDecoded> {
    let (header, mut off) = ColorHeader::read(bytes)?;
    let sub = tag_subsampling(header.subsampling)?;
    let (w, h) = (header.width as usize, header.height as usize);
    let (cw, ch) = sub.chroma_dims(w, h);
    let want = [(w, h), (cw, ch), (cw, ch)];
    let mut planes = Vec::with_capacity(3);
    for (i, &(ew, eh)) in want.iter().enumerate() {
        if bytes.len() < off + 4 {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "truncated plane {i} length"
            );
        }
        let len = u32::from_le_bytes([
            bytes[off],
            bytes[off + 1],
            bytes[off + 2],
            bytes[off + 3],
        ]) as usize;
        off += 4;
        if bytes.len() < off + len {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "plane {i} truncated: header says {len}, {} available",
                bytes.len() - off
            );
        }
        let dec = decoder::decode(&bytes[off..off + len])
            .with_context(|| format!("decoding plane {i}"))?;
        off += len;
        let ph = &dec.header;
        if (ph.width as usize, ph.height as usize) != (ew, eh) {
            decode_bail!(
                DecodeErrorKind::BadHeader,
                "plane {i} is {}x{}, expected {ew}x{eh}",
                ph.width,
                ph.height
            );
        }
        if ph.quality != header.quality
            || ph.variant != header.variant
        {
            decode_bail!(
                DecodeErrorKind::BadHeader,
                "plane {i} quality/variant ({}, {}) disagrees with \
                 container ({}, {})",
                ph.quality,
                ph.variant,
                header.quality,
                header.variant
            );
        }
        planes.push(PlaneCoef {
            qcoef: dec.qcoef_planar,
            width: ew,
            height: eh,
            padded_width: ph.padded_width as usize,
            padded_height: ph.padded_height as usize,
        });
    }
    let planes: [PlaneCoef; 3] = match planes.try_into() {
        Ok(p) => p,
        Err(_) => unreachable!("exactly three planes pushed"),
    };
    Ok(ColorDecoded { header, planes })
}

/// Scan for the magic of the next embedded plane stream (`CDC1` or
/// `CDC2`), starting at `from`. Used to re-anchor after a corrupted
/// plane length field.
fn scan_next_plane_magic(bytes: &[u8], from: usize) -> Option<usize> {
    let mut q = from;
    while q + 4 <= bytes.len() {
        if &bytes[q..q + 4] == MAGIC || &bytes[q..q + 4] == MAGIC_V2 {
            return Some(q);
        }
        q += 1;
    }
    None
}

/// A fully concealed plane: mid-gray (all-zero coefficients) at the
/// expected geometry, reported as one damaged, unconcealable segment.
fn concealed_plane(
    ew: usize,
    eh: usize,
    skipped: usize,
) -> (PlaneCoef, PlaneSalvage) {
    let pw = ew.next_multiple_of(8);
    let ph = eh.next_multiple_of(8);
    (
        PlaneCoef {
            qcoef: vec![0.0; pw * ph],
            width: ew,
            height: eh,
            padded_width: pw,
            padded_height: ph,
        },
        PlaneSalvage {
            segments_total: 1,
            segments_damaged: 1,
            segments_concealed: 0,
            bytes_skipped: skipped as u64,
        },
    )
}

/// Damage-tolerant decode of a `CDC3` container. The color header must
/// be intact; everything after it is salvageable. Per plane:
///
/// * the embedded stream goes through the grayscale salvage decoder
///   (per-segment crc + concealment for `CDC2` planes);
/// * a corrupted plane length field triggers a scan for the next
///   plane's magic so later planes are not lost;
/// * a plane whose head is unusable (or whose geometry disagrees with
///   the color header) is concealed whole as mid-gray.
pub fn decode_salvage(
    bytes: &[u8],
) -> Result<(ColorDecoded, SalvageReport)> {
    let (header, mut off) = ColorHeader::read(bytes)?;
    let sub = tag_subsampling(header.subsampling)?;
    let (w, h) = (header.width as usize, header.height as usize);
    let (cw, ch) = sub.chroma_dims(w, h);
    let want = [(w, h), (cw, ch), (cw, ch)];
    let mut planes = Vec::with_capacity(3);
    let mut reports = Vec::with_capacity(3);
    for &(ew, eh) in want.iter() {
        if bytes.len() < off + 4 {
            // ran off the end: conceal this and all remaining planes
            let (p, r) = concealed_plane(ew, eh, bytes.len() - off);
            planes.push(p);
            reports.push(r);
            off = bytes.len();
            continue;
        }
        let len = u32::from_le_bytes([
            bytes[off],
            bytes[off + 1],
            bytes[off + 2],
            bytes[off + 3],
        ]) as usize;
        let (slice, next_off) = if bytes.len() >= off + 4 + len {
            (&bytes[off + 4..off + 4 + len], off + 4 + len)
        } else {
            // implausible length: re-anchor on the next plane magic
            // (its u32 length field sits right before it)
            match scan_next_plane_magic(bytes, off + 8) {
                Some(q) if q >= off + 8 => {
                    (&bytes[off + 4..q - 4], q - 4)
                }
                _ => (&bytes[off + 4..], bytes.len()),
            }
        };
        let (plane, report) =
            match decoder::decode_salvage_plane(slice) {
                Ok((dec, ps))
                    if (dec.header.width as usize,
                        dec.header.height as usize)
                        == (ew, eh)
                        && dec.header.quality == header.quality
                        && dec.header.variant == header.variant =>
                {
                    (
                        PlaneCoef {
                            qcoef: dec.qcoef_planar,
                            width: ew,
                            height: eh,
                            padded_width: dec.header.padded_width
                                as usize,
                            padded_height: dec.header.padded_height
                                as usize,
                        },
                        ps,
                    )
                }
                // geometry mismatch or unusable plane head
                _ => concealed_plane(ew, eh, slice.len()),
            };
        planes.push(plane);
        reports.push(report);
        off = next_off;
    }
    let planes: [PlaneCoef; 3] = match planes.try_into() {
        Ok(p) => p,
        Err(_) => unreachable!("exactly three planes pushed"),
    };
    Ok((
        ColorDecoded { header, planes },
        SalvageReport::from_planes(reports),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::variant_tag;
    use crate::dct::color::ColorPipeline;
    use crate::dct::Variant;
    use crate::image::synthetic;
    use crate::metrics::color::psnr_color;
    use crate::util::prng::Rng;

    fn make(
        w: usize,
        h: usize,
        sub: Subsampling,
        quality: u8,
    ) -> (ColorHeader, [PlaneCoef; 3], ColorPipeline) {
        let img = synthetic::lena_like_rgb(w, h, 5);
        let pipe = ColorPipeline::new(Variant::Dct, quality, sub);
        let planes = pipe.analyze(&img);
        let header = ColorHeader {
            width: w as u32,
            height: h as u32,
            quality,
            variant: variant_tag(Variant::Dct),
            subsampling: subsampling_tag(sub),
        };
        (header, planes, pipe)
    }

    #[test]
    fn header_roundtrip() {
        let h = ColorHeader {
            width: 640,
            height: 480,
            quality: 75,
            variant: 2,
            subsampling: 2,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        let (back, used) = ColorHeader::read(&buf).unwrap();
        assert_eq!(h, back);
        assert_eq!(used, ColorHeader::BYTES);
        assert!(is_color_container(&buf));
        assert!(!is_color_container(b"CDC1"));
    }

    #[test]
    fn subsampling_tags_roundtrip() {
        for s in Subsampling::ALL {
            assert_eq!(tag_subsampling(subsampling_tag(s)).unwrap(), s);
        }
        assert!(tag_subsampling(9).is_err());
    }

    #[test]
    fn roundtrip_exact_coefficients() {
        for sub in Subsampling::ALL {
            let (header, planes, _) = make(64, 48, sub, 50);
            let bytes = encode(&header, &planes).unwrap();
            let dec = decode(&bytes).unwrap();
            assert_eq!(dec.header, header);
            assert_eq!(dec.planes, planes, "{}", sub.as_str());
        }
    }

    #[test]
    fn roundtrip_odd_size() {
        let (header, planes, pipe) =
            make(30, 21, Subsampling::S420, 75);
        let bytes = encode(&header, &planes).unwrap();
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.planes[1].width, 15);
        assert_eq!(dec.planes[1].padded_width, 16);
        // full file -> image path
        let img = synthetic::lena_like_rgb(30, 21, 5);
        let recon = pipe.decode_coefficients(&dec.planes);
        assert!(psnr_color(&img, &recon).weighted > 25.0);
    }

    #[test]
    fn scanned_container_byte_identical() {
        // the fused-output color front door emits the same container
        let img = synthetic::lena_like_rgb(40, 21, 8);
        let pipe =
            ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420);
        let out = pipe.compress(&img);
        let header = ColorHeader {
            width: 40,
            height: 21,
            quality: 50,
            variant: variant_tag(Variant::Cordic),
            subsampling: subsampling_tag(Subsampling::S420),
        };
        let via_planar = encode(&header, &out.planes).unwrap();
        let via_scanned = encode_scanned(&header, &out.scanned).unwrap();
        assert_eq!(via_planar, via_scanned);
        // wrong plane dims still rejected on the scanned path
        let mut swapped = out.scanned.clone();
        swapped.swap(0, 1);
        assert!(encode_scanned(&header, &swapped).is_err());
    }

    #[test]
    fn color_beats_gray_times_three() {
        // the whole point of 4:2:0: three planes must cost far less than
        // three luma planes
        let img = synthetic::lena_like_rgb(96, 96, 2);
        let (header, planes, _) = make(96, 96, Subsampling::S420, 50);
        let bytes = encode(&header, &planes).unwrap();
        assert!(
            bytes.len() * 2 < img.bytes(),
            "{} vs raw {}",
            bytes.len(),
            img.bytes()
        );
    }

    #[test]
    fn wrong_plane_dims_rejected_on_encode() {
        let (header, mut planes, _) =
            make(64, 48, Subsampling::S420, 50);
        planes.swap(0, 1); // luma slot now has chroma dims
        assert!(encode(&header, &planes).is_err());
    }

    #[test]
    fn truncated_and_corrupt_error_not_panic() {
        let (header, planes, _) = make(32, 32, Subsampling::S422, 50);
        let bytes = encode(&header, &planes).unwrap();
        for cut in
            [3, ColorHeader::BYTES - 1, ColorHeader::BYTES + 2,
             bytes.len() - 5]
        {
            assert!(decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let mut corrupt = bytes.clone();
            for _ in 0..rng.range_i64(1, 6) {
                let i = rng.below(corrupt.len() as u64) as usize;
                corrupt[i] ^= 1 << rng.below(8);
            }
            let _ = decode(&corrupt); // Ok or Err, never panic
        }
    }

    #[test]
    fn v2_roundtrip_and_clean_salvage() {
        for interval in [0u16, 2, 4] {
            let (header, planes, _) =
                make(64, 48, Subsampling::S420, 50);
            let bytes = encode_v2(&header, &planes, interval).unwrap();
            let dec = decode(&bytes).unwrap();
            assert_eq!(dec.planes, planes, "interval {interval}");
            let (sdec, report) = decode_salvage(&bytes).unwrap();
            assert_eq!(sdec.planes, planes);
            assert!(report.is_clean(), "{report:?}");
            assert_eq!(report.per_plane.len(), 3);
        }
    }

    #[test]
    fn v1_salvage_is_strict_roundtrip() {
        let (header, planes, _) = make(48, 32, Subsampling::S444, 75);
        let bytes = encode(&header, &planes).unwrap();
        let (dec, report) = decode_salvage(&bytes).unwrap();
        assert_eq!(dec.planes, planes);
        assert!(report.is_clean());
        assert_eq!(report.segments_total, 3);
    }

    #[test]
    fn v2_salvage_conceals_flipped_plane_payload() {
        let (header, planes, _) = make(64, 64, Subsampling::S420, 50);
        let bytes = encode_v2(&header, &planes, 1).unwrap();
        // flip a bit near the end of the luma plane's segment data
        let y_len = u32::from_le_bytes(
            bytes[ColorHeader::BYTES..ColorHeader::BYTES + 4]
                .try_into()
                .unwrap(),
        ) as usize;
        let mut corrupt = bytes.clone();
        let pos = ColorHeader::BYTES + 4 + y_len - y_len / 8;
        corrupt[pos] ^= 0x40;
        assert!(decode(&corrupt).is_err());
        let (dec, report) = decode_salvage(&corrupt).unwrap();
        assert!(report.segments_damaged >= 1, "{report:?}");
        assert!(!report.is_clean());
        // chroma planes untouched
        assert_eq!(dec.planes[1], planes[1]);
        assert_eq!(dec.planes[2], planes[2]);
    }

    #[test]
    fn salvage_recovers_later_planes_after_bad_length_field() {
        let (header, planes, _) = make(48, 48, Subsampling::S420, 50);
        let bytes = encode_v2(&header, &planes, 2).unwrap();
        let mut corrupt = bytes.clone();
        // blow up the luma plane's u32 length field
        corrupt[ColorHeader::BYTES + 3] = 0xFF;
        assert!(decode(&corrupt).is_err());
        let (dec, report) = decode_salvage(&corrupt).unwrap();
        // luma still decodes (its bytes are intact, only the outer
        // length lied); chroma re-anchored via magic scan
        assert_eq!(dec.planes[0], planes[0], "{report:?}");
        assert_eq!(dec.planes[1], planes[1]);
        assert_eq!(dec.planes[2], planes[2]);
    }

    #[test]
    fn salvage_conceals_destroyed_plane_head() {
        let (header, planes, _) = make(32, 32, Subsampling::S444, 50);
        let bytes = encode_v2(&header, &planes, 2).unwrap();
        let mut corrupt = bytes.clone();
        // wreck the chroma-1 plane magic so its head is unusable
        let y_len = u32::from_le_bytes(
            bytes[ColorHeader::BYTES..ColorHeader::BYTES + 4]
                .try_into()
                .unwrap(),
        ) as usize;
        let cb_magic = ColorHeader::BYTES + 4 + y_len + 4;
        corrupt[cb_magic] = b'X';
        let (dec, report) = decode_salvage(&corrupt).unwrap();
        assert!(report.segments_damaged >= 1);
        assert_eq!(dec.planes[0], planes[0]);
        // concealed plane keeps the expected geometry
        assert_eq!(dec.planes[1].width, planes[1].width);
        assert_eq!(dec.planes[1].padded_width, planes[1].padded_width);
        assert!(dec.planes[1].qcoef.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn gray_decoder_rejects_color_container() {
        let (header, planes, _) = make(16, 16, Subsampling::S444, 50);
        let bytes = encode(&header, &planes).unwrap();
        assert!(crate::codec::decoder::decode(&bytes).is_err());
    }
}
