//! JPEG-style symbol model for one 8x8 block of quantized coefficients:
//! DC as DPCM magnitude-category + sign-extended bits, AC as (run, size)
//! pairs with ZRL (16-zero run) and EOB markers.
//!
//! Symbols (what the Huffman coder sees):
//!   DC: category 0..=11 (number of magnitude bits)
//!   AC: (run << 4) | size, run 0..=15, size 1..=10; 0x00 = EOB,
//!       0xF0 = ZRL.
//! Each symbol is followed by `size` raw magnitude bits in the JPEG
//! one's-complement convention for negatives.

use anyhow::{bail, Result};

use crate::util::bitio::{BitReader, BitWriter};

pub const EOB: u8 = 0x00;
pub const ZRL: u8 = 0xF0;

/// Magnitude category: number of bits needed for |v| (0 for v == 0).
#[inline]
pub fn category(v: i32) -> u32 {
    (32 - v.unsigned_abs().leading_zeros()).min(31)
}

/// JPEG magnitude bits: positive values verbatim; negative values encoded
/// as v - 1 masked to `size` bits (one's complement).
#[inline]
pub fn magnitude_bits(v: i32, size: u32) -> u64 {
    debug_assert!(size > 0);
    if v >= 0 {
        v as u64
    } else {
        ((v - 1) & ((1i64 << size) as i32 - 1).max(0)) as u64
            & ((1u64 << size) - 1)
    }
}

/// Decode magnitude bits back to a value.
#[inline]
pub fn decode_magnitude(bits: u64, size: u32) -> i32 {
    debug_assert!(size > 0);
    let v = bits as i32;
    if (bits >> (size - 1)) & 1 == 1 {
        v // positive: MSB set
    } else {
        v - ((1i32 << size) - 1) // negative
    }
}

/// One block's symbol stream, produced before Huffman coding (also the
/// statistics pass input).
#[derive(Debug, Default, Clone)]
pub struct BlockSymbols {
    /// (dc_category, magnitude bits)
    pub dc: (u8, u64),
    /// AC symbols: (symbol byte, magnitude bits)
    pub ac: Vec<(u8, u64)>,
}

/// Encode one zigzag-ordered block against the previous block's DC.
pub fn encode_block(scan: &[i16; 64], prev_dc: i16) -> BlockSymbols {
    let diff = scan[0] as i32 - prev_dc as i32;
    let dc_cat = category(diff);
    let dc = (
        dc_cat as u8,
        if dc_cat == 0 {
            0
        } else {
            magnitude_bits(diff, dc_cat)
        },
    );

    let mut ac = Vec::new();
    let mut run = 0u32;
    // index of last nonzero AC
    let last_nz = (1..64).rev().find(|&i| scan[i] != 0);
    let end = last_nz.map(|i| i + 1).unwrap_or(1);
    for &c in &scan[1..end] {
        if c == 0 {
            run += 1;
            if run == 16 {
                ac.push((ZRL, 0));
                run = 0;
            }
            continue;
        }
        let v = c as i32;
        let size = category(v);
        debug_assert!(size <= 15);
        ac.push((((run as u8) << 4) | size as u8, magnitude_bits(v, size)));
        run = 0;
    }
    if end < 64 {
        ac.push((EOB, 0));
    }
    BlockSymbols { dc, ac }
}

/// Append a block's magnitude bits + symbols to the bitstream using
/// caller-provided symbol writers (Huffman lives a layer up).
pub fn write_block<FD, FA>(
    w: &mut BitWriter,
    sym: &BlockSymbols,
    mut put_dc: FD,
    mut put_ac: FA,
) where
    FD: FnMut(&mut BitWriter, u8),
    FA: FnMut(&mut BitWriter, u8),
{
    put_dc(w, sym.dc.0);
    if sym.dc.0 > 0 {
        w.put(sym.dc.1, sym.dc.0 as u32);
    }
    for &(s, bits) in &sym.ac {
        put_ac(w, s);
        let size = (s & 0x0F) as u32;
        if size > 0 {
            w.put(bits, size);
        }
    }
}

/// Read one block back (zigzag order), given symbol readers.
pub fn read_block<FD, FA>(
    r: &mut BitReader<'_>,
    prev_dc: i16,
    mut get_dc: FD,
    mut get_ac: FA,
) -> Result<[i16; 64]>
where
    FD: FnMut(&mut BitReader<'_>) -> Result<u8>,
    FA: FnMut(&mut BitReader<'_>) -> Result<u8>,
{
    let mut scan = [0i16; 64];
    let dc_cat = get_dc(r)? as u32;
    let diff = if dc_cat == 0 {
        0
    } else {
        if dc_cat > 15 {
            bail!("corrupt DC category {dc_cat}");
        }
        decode_magnitude(r.get(dc_cat)?, dc_cat)
    };
    scan[0] = (prev_dc as i32 + diff)
        .clamp(i16::MIN as i32, i16::MAX as i32) as i16;

    let mut i = 1usize;
    while i < 64 {
        let s = get_ac(r)?;
        if s == EOB {
            break;
        }
        if s == ZRL {
            i += 16;
            continue;
        }
        let run = (s >> 4) as usize;
        let size = (s & 0x0F) as u32;
        if size == 0 {
            bail!("corrupt AC symbol {s:#04x} (zero size, not EOB/ZRL)");
        }
        i += run;
        if i >= 64 {
            bail!("AC run overflows block (i = {i})");
        }
        scan[i] = decode_magnitude(r.get(size)?, size)
            .clamp(i16::MIN as i32, i16::MAX as i32)
            as i16;
        i += 1;
    }
    Ok(scan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn category_values() {
        assert_eq!(category(0), 0);
        assert_eq!(category(1), 1);
        assert_eq!(category(-1), 1);
        assert_eq!(category(2), 2);
        assert_eq!(category(-3), 2);
        assert_eq!(category(255), 8);
        assert_eq!(category(-1024), 11);
    }

    #[test]
    fn magnitude_roundtrip() {
        for v in [-1024, -255, -2, -1, 1, 2, 3, 127, 1023] {
            let s = category(v);
            let bits = magnitude_bits(v, s);
            assert_eq!(decode_magnitude(bits, s), v, "v {v}");
        }
    }

    fn raw_write_read(scan: &[i16; 64], prev: i16) -> [i16; 64] {
        // identity "Huffman": write symbols as raw bytes
        let sym = encode_block(scan, prev);
        let mut w = BitWriter::new();
        write_block(
            &mut w,
            &sym,
            |w, s| w.put(s as u64, 8),
            |w, s| w.put(s as u64, 8),
        );
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        read_block(
            &mut r,
            prev,
            |r| Ok(r.get(8)? as u8),
            |r| Ok(r.get(8)? as u8),
        )
        .unwrap()
    }

    #[test]
    fn block_roundtrip_sparse() {
        let mut scan = [0i16; 64];
        scan[0] = -37;
        scan[3] = 5;
        scan[20] = -1;
        scan[63] = 2;
        assert_eq!(raw_write_read(&scan, 10), scan);
    }

    #[test]
    fn block_roundtrip_zero_block() {
        let scan = [0i16; 64];
        assert_eq!(raw_write_read(&scan, -5), scan);
    }

    #[test]
    fn block_roundtrip_dense_random() {
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let mut scan = [0i16; 64];
            for v in &mut scan {
                if rng.chance(0.4) {
                    *v = rng.range_i64(-400, 400) as i16;
                }
            }
            let prev = rng.range_i64(-500, 500) as i16;
            assert_eq!(raw_write_read(&scan, prev), scan);
        }
    }

    #[test]
    fn long_zero_runs_use_zrl() {
        let mut scan = [0i16; 64];
        scan[40] = 7; // 39 zeros -> 2 ZRL + run 7
        let sym = encode_block(&scan, 0);
        let zrls = sym.ac.iter().filter(|(s, _)| *s == ZRL).count();
        assert_eq!(zrls, 2);
    }

    #[test]
    fn trailing_zeros_emit_eob() {
        let mut scan = [0i16; 64];
        scan[1] = 3;
        let sym = encode_block(&scan, 0);
        assert_eq!(sym.ac.last().unwrap().0, EOB);
        // full block (last coefficient nonzero) has no EOB
        let mut full = [1i16; 64];
        full[0] = 9;
        let sym = encode_block(&full, 0);
        assert_ne!(sym.ac.last().unwrap().0, EOB);
    }

    #[test]
    fn dpcm_uses_previous_dc() {
        let mut scan = [0i16; 64];
        scan[0] = 100;
        let sym_same = encode_block(&scan, 100);
        assert_eq!(sym_same.dc.0, 0); // zero diff -> category 0
        let sym_diff = encode_block(&scan, 0);
        assert_eq!(sym_diff.dc.0 as u32, category(100));
    }
}
