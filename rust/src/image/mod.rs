//! Image types and I/O.
//!
//! The paper's experiments are all on 8-bit grayscale (Lena / Cable-car),
//! carried as `GrayImage`: row-major `u8` with `f32` conversion helpers
//! for the transform layers. The color workload rides on top: [`color`]
//! holds the interleaved-RGB [`ColorImage`] boundary type and [`ycbcr`]
//! decomposes it into Y/Cb/Cr `GrayImage` planes (with 4:4:4 / 4:2:2 /
//! 4:2:0 chroma subsampling) so every transform stage stays grayscale.

pub mod bmp;
pub mod color;
pub mod histeq;
pub mod pgm;
pub mod png;
pub mod resize;
pub mod synthetic;
pub mod ycbcr;

pub use color::ColorImage;

use anyhow::{bail, Context, Result};
use std::path::Path;

/// 8-bit grayscale image, row-major.
#[derive(Clone, PartialEq, Eq)]
pub struct GrayImage {
    pub width: usize,
    pub height: usize,
    pub data: Vec<u8>,
}

impl std::fmt::Debug for GrayImage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GrayImage({}x{})", self.width, self.height)
    }
}

impl GrayImage {
    pub fn new(width: usize, height: usize) -> Self {
        GrayImage {
            width,
            height,
            data: vec![0; width * height],
        }
    }

    pub fn from_vec(width: usize, height: usize, data: Vec<u8>) -> Result<Self> {
        if data.len() != width * height {
            bail!(
                "pixel count {} != {}x{}",
                data.len(),
                width,
                height
            );
        }
        Ok(GrayImage {
            width,
            height,
            data,
        })
    }

    /// Build from f32 samples (clamped to 0..255, rounded).
    pub fn from_f32(width: usize, height: usize, data: &[f32]) -> Result<Self> {
        if data.len() != width * height {
            bail!("pixel count {} != {}x{}", data.len(), width, height);
        }
        Ok(GrayImage {
            width,
            height,
            data: data
                .iter()
                .map(|&v| v.clamp(0.0, 255.0).round() as u8)
                .collect(),
        })
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> u8 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: u8) {
        self.data[y * self.width + x] = v;
    }

    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// Row-major f32 copy (0..255 values).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Crop to `(w, h)` anchored at the top-left.
    pub fn crop(&self, w: usize, h: usize) -> Result<GrayImage> {
        if w > self.width || h > self.height {
            bail!(
                "crop {}x{} exceeds image {}x{}",
                w, h, self.width, self.height
            );
        }
        let mut out = GrayImage::new(w, h);
        for y in 0..h {
            let src = &self.data[y * self.width..y * self.width + w];
            out.data[y * w..(y + 1) * w].copy_from_slice(src);
        }
        Ok(out)
    }

    /// Pad to `(w, h)` >= current size with edge replication (the block
    /// manager uses this to reach 8-multiples without ringing artifacts).
    pub fn pad_edge(&self, w: usize, h: usize) -> Result<GrayImage> {
        if w < self.width || h < self.height {
            bail!(
                "pad target {}x{} smaller than image {}x{}",
                w, h, self.width, self.height
            );
        }
        let mut out = GrayImage::new(w, h);
        for y in 0..h {
            let sy = y.min(self.height - 1);
            for x in 0..w {
                let sx = x.min(self.width - 1);
                out.data[y * w + x] = self.get(sx, sy);
            }
        }
        Ok(out)
    }

    /// Load by extension: .pgm/.ppm, .bmp, .png.
    pub fn load(path: impl AsRef<Path>) -> Result<GrayImage> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        match ext(path).as_deref() {
            Some("pgm") | Some("ppm") => pgm::decode(&bytes),
            Some("bmp") => bmp::decode(&bytes),
            Some("png") => png::decode(&bytes),
            _ => bail!("unsupported image extension: {}", path.display()),
        }
    }

    /// Save by extension: .pgm, .ppm (P6, channels replicated), .bmp,
    /// .png.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let bytes = match ext(path).as_deref() {
            Some("pgm") => pgm::encode(self),
            Some("ppm") => {
                pgm::encode_rgb(&ColorImage::from_gray(self))
            }
            Some("bmp") => bmp::encode(self),
            Some("png") => png::encode(self)?,
            _ => bail!("unsupported image extension: {}", path.display()),
        };
        std::fs::write(path, bytes)
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Mean pixel value.
    pub fn mean(&self) -> f64 {
        self.data.iter().map(|&v| v as f64).sum::<f64>()
            / self.pixels() as f64
    }

    /// Pixel standard deviation (contrast proxy).
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self
            .data
            .iter()
            .map(|&v| {
                let d = v as f64 - m;
                d * d
            })
            .sum::<f64>()
            / self.pixels() as f64;
        var.sqrt()
    }
}

fn ext(path: &Path) -> Option<String> {
    path.extension()
        .and_then(|e| e.to_str())
        .map(|e| e.to_ascii_lowercase())
}

/// BT.601 luma of an RGB triple (already-scaled f32 channels) — the one
/// formula every color-to-gray conversion in this module shares.
pub(crate) fn luma_f32(r: f32, g: f32, b: f32) -> u8 {
    (0.299 * r + 0.587 * g + 0.114 * b)
        .round()
        .clamp(0.0, 255.0) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates() {
        assert!(GrayImage::from_vec(4, 4, vec![0; 15]).is_err());
        assert!(GrayImage::from_vec(4, 4, vec![0; 16]).is_ok());
    }

    #[test]
    fn from_f32_clamps_and_rounds() {
        let img =
            GrayImage::from_f32(2, 1, &[-5.0, 300.2]).unwrap();
        assert_eq!(img.data, vec![0, 255]);
        let img = GrayImage::from_f32(2, 1, &[1.4, 1.6]).unwrap();
        assert_eq!(img.data, vec![1, 2]);
    }

    #[test]
    fn crop_keeps_topleft() {
        let mut img = GrayImage::new(4, 4);
        for y in 0..4 {
            for x in 0..4 {
                img.set(x, y, (y * 4 + x) as u8);
            }
        }
        let c = img.crop(2, 3).unwrap();
        assert_eq!(c.width, 2);
        assert_eq!(c.height, 3);
        assert_eq!(c.get(1, 2), img.get(1, 2));
        assert!(img.crop(5, 1).is_err());
    }

    #[test]
    fn pad_edge_replicates() {
        let img = GrayImage::from_vec(2, 2, vec![1, 2, 3, 4]).unwrap();
        let p = img.pad_edge(4, 3).unwrap();
        assert_eq!(p.get(3, 0), 2); // right edge of row 0
        assert_eq!(p.get(0, 2), 3); // bottom edge of col 0
        assert_eq!(p.get(3, 2), 4); // corner
        assert!(img.pad_edge(1, 4).is_err());
    }

    #[test]
    fn stats() {
        let img = GrayImage::from_vec(2, 1, vec![0, 200]).unwrap();
        assert_eq!(img.mean(), 100.0);
        assert_eq!(img.stddev(), 100.0);
    }
}
