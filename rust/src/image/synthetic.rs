//! Synthetic test images — the stand-ins for the paper's Lena and
//! Cable-car (Marco Schmidt's test-image database is not redistributable
//! in this environment; DESIGN.md §Hardware-Adaptation documents the
//! substitution).
//!
//! What the experiments actually need from the content:
//!
//! * DCT timing (Tables 1-2) is content-independent — any pixels do;
//! * PSNR (Tables 3-4) needs a *natural-image spectrum* (energy
//!   concentrated at low frequencies, ~1/f^2 falloff) so quantization
//!   behaves as it does on photographs;
//! * the CPU/GPU processed figures need recognizable structure.
//!
//! `lena_like` produces a smooth portrait-spectrum image via diamond-square
//! plasma noise plus a soft radial subject; `cablecar_like` produces a
//! scene with hard edges, periodic texture (cables) and gradient sky —
//! higher high-frequency energy, which is why the paper's Cable-car PSNR
//! values sit below Lena's at equal size, a shape our stand-ins preserve.

use crate::util::prng::Rng;

use super::GrayImage;

/// Diamond-square ("plasma") fractal noise field in 0..1, at any size.
fn plasma(width: usize, height: usize, seed: u64, roughness: f64) -> Vec<f64> {
    // run diamond-square on the smallest 2^n+1 square covering the image,
    // then crop.
    let n = width.max(height).max(2);
    let mut size = 1usize;
    while size + 1 < n {
        size <<= 1;
    }
    let dim = size + 1;
    let mut g = vec![0.0f64; dim * dim];
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| y * dim + x;
    g[idx(0, 0)] = rng.next_f64();
    g[idx(size, 0)] = rng.next_f64();
    g[idx(0, size)] = rng.next_f64();
    g[idx(size, size)] = rng.next_f64();
    let mut step = size;
    let mut amp = 1.0f64;
    while step > 1 {
        let half = step / 2;
        // diamond
        for y in (half..dim).step_by(step) {
            for x in (half..dim).step_by(step) {
                let avg = (g[idx(x - half, y - half)]
                    + g[idx(x + half, y - half)]
                    + g[idx(x - half, y + half)]
                    + g[idx(x + half, y + half)])
                    / 4.0;
                g[idx(x, y)] = avg + (rng.next_f64() - 0.5) * amp;
            }
        }
        // square
        for y in (0..dim).step_by(half) {
            let x0 = if (y / half) % 2 == 0 { half } else { 0 };
            for x in (x0..dim).step_by(step) {
                let mut sum = 0.0;
                let mut cnt = 0.0;
                if x >= half {
                    sum += g[idx(x - half, y)];
                    cnt += 1.0;
                }
                if x + half < dim {
                    sum += g[idx(x + half, y)];
                    cnt += 1.0;
                }
                if y >= half {
                    sum += g[idx(x, y - half)];
                    cnt += 1.0;
                }
                if y + half < dim {
                    sum += g[idx(x, y + half)];
                    cnt += 1.0;
                }
                g[idx(x, y)] = sum / cnt + (rng.next_f64() - 0.5) * amp;
            }
        }
        step = half;
        amp *= roughness;
    }
    // crop + normalize to 0..1
    let mut out = vec![0.0f64; width * height];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for y in 0..height {
        for x in 0..width {
            let v = g[idx(x * size / width.max(1), y * size / height.max(1))];
            out[y * width + x] = v;
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    let span = (hi - lo).max(1e-12);
    for v in &mut out {
        *v = (*v - lo) / span;
    }
    out
}

/// Portrait-spectrum stand-in for Lena: plasma base, a rough "texture"
/// octave set (hair/feathers in the original have strong mid/high
/// frequencies — without them quantization error dominates and the
/// Cordic-vs-DCT gap of Tables 3-4 would vanish), soft radial subject,
/// gentle vignette, film grain.
pub fn lena_like(width: usize, height: usize, seed: u64) -> GrayImage {
    let base = plasma(width, height, seed, 0.55);
    // high-roughness field: keeps fine scales near full amplitude,
    // supplying the AC energy a real photograph has
    let detail = plasma(width, height, seed ^ 0x7E7E, 0.9);
    let mut rng = Rng::new(seed ^ 0xA11CE);
    let (cw, ch) = (width as f64 / 2.0, height as f64 / 2.0);
    let rad = cw.min(ch);
    let mut data = Vec::with_capacity(width * height);
    for y in 0..height {
        for x in 0..width {
            let p = base[y * width + x];
            let d = detail[y * width + x] - 0.5;
            let dx = (x as f64 - cw * 0.92) / rad;
            let dy = (y as f64 - ch * 1.05) / rad;
            let r = (dx * dx + dy * dy).sqrt();
            // soft "subject" bump and vignette falloff
            let subject = 0.35 * (-(r * 1.8).powi(2)).exp();
            let vignette = 1.0 - 0.25 * (r / 1.4).clamp(0.0, 1.0).powi(2);
            // texture is strongest around the subject ring (hair zone)
            let texture_amp = 0.10 + 0.08 * (-(r - 0.9).powi(2) * 4.0).exp();
            // oriented mid-frequency "feather/hair" striation: period
            // ~4.5 px, phase-warped by the detail field. This is the
            // content that energizes the mid-band DCT coefficients —
            // locally-linear plasma alone leaves X2/X6 empty and would
            // erase the paper's Cordic-vs-DCT PSNR gap.
            let stripe = (std::f64::consts::TAU
                * (0.16 * x as f64 + 0.13 * y as f64)
                + 7.0 * d)
                .sin();
            let grain = (rng.next_f64() - 0.5) * 0.04;
            let v = ((0.25 + 0.50 * p + subject + texture_amp * d
                + 0.09 * stripe * (0.3 + p))
                * vignette
                + grain)
                .clamp(0.0, 1.0);
            data.push((v * 255.0).round() as u8);
        }
    }
    GrayImage {
        width,
        height,
        data,
    }
}

/// Scene-spectrum stand-in for Cable-car: gradient sky, mountain silhouette
/// (hard edge), periodic cables, boxy car, textured ground.
pub fn cablecar_like(width: usize, height: usize, seed: u64) -> GrayImage {
    let tex = plasma(width, height, seed ^ 0xCAB1E, 0.8);
    let clouds = plasma(width, height, seed ^ 0xC10D, 0.75);
    let ridge = plasma(width.max(2), 1, seed ^ 0x51DE, 0.5);
    let mut rng = Rng::new(seed ^ 0xF0_6F);
    let mut data = Vec::with_capacity(width * height);
    let fw = width as f64;
    let fh = height as f64;
    // cable car body rectangle
    let car_x0 = (0.42 * fw) as usize;
    let car_x1 = (0.58 * fw) as usize;
    let car_y0 = (0.38 * fh) as usize;
    let car_y1 = (0.55 * fh) as usize;
    for y in 0..height {
        for x in 0..width {
            let xf = x as f64 / fw;
            let yf = y as f64 / fh;
            // sky gradient with cloud texture
            let mut v = 0.85 - 0.35 * yf
                + 0.12 * (clouds[y * width + x] - 0.5);
            // mountain silhouette: ridge height per column
            let ridge_h = 0.55 + 0.30 * ridge[x.min(width - 1)];
            if yf > ridge_h {
                // below the ridge: dark rocky slope (high-frequency)
                v = 0.22 + 0.45 * tex[y * width + x];
            }
            // two catenary-ish cables
            for (k, amp) in [(0.30f64, 0.05f64), (0.34, 0.045)] {
                let cable_y = k + amp * (xf * 2.0 - 1.0).powi(2);
                if (yf - cable_y).abs() < 1.2 / fh {
                    v = 0.05;
                }
            }
            // the car
            if (car_x0..car_x1).contains(&x) && (car_y0..car_y1).contains(&y)
            {
                let frame = x < car_x0 + 2
                    || x >= car_x1 - 2
                    || y < car_y0 + 2
                    || y >= car_y1 - 2;
                v = if frame { 0.10 } else { 0.55 };
                // windows
                let wx = (x - car_x0) * 5 / (car_x1 - car_x0).max(1);
                if !frame && y < car_y0 + (car_y1 - car_y0) / 2 && wx % 2 == 1
                {
                    v = 0.80;
                }
            }
            let grain = (rng.next_f64() - 0.5) * 0.05;
            data.push((((v + grain).clamp(0.0, 1.0)) * 255.0).round() as u8);
        }
    }
    GrayImage {
        width,
        height,
        data,
    }
}

/// Colorize a grayscale scene: the gray image becomes the luma plane and
/// two low-roughness plasma fields become smooth chroma. Natural images
/// carry far less chroma bandwidth than luma — exactly the property that
/// makes 4:2:0 subsampling nearly free, which the chroma ablation
/// measures — so the chroma fields are deliberately smoother than the
/// luma content.
pub fn colorize(gray: &GrayImage, seed: u64) -> super::ColorImage {
    let (w, h) = (gray.width, gray.height);
    let cb_f = plasma(w, h, seed ^ 0xCB_CB, 0.45);
    let cr_f = plasma(w, h, seed ^ 0xC6_C6, 0.45);
    let chroma_plane = |f: &[f64]| GrayImage {
        width: w,
        height: h,
        data: f
            .iter()
            .map(|&v| {
                (128.0 + 96.0 * (v - 0.5)).clamp(0.0, 255.0).round()
                    as u8
            })
            .collect(),
    };
    super::ycbcr::ycbcr_to_rgb(
        gray,
        &chroma_plane(&cb_f),
        &chroma_plane(&cr_f),
    )
    .expect("same-size planes")
}

/// Color variant of [`lena_like`].
pub fn lena_like_rgb(width: usize, height: usize, seed: u64)
                     -> super::ColorImage {
    colorize(&lena_like(width, height, seed), seed ^ 0xC0_10)
}

/// Color variant of [`cablecar_like`].
pub fn cablecar_like_rgb(width: usize, height: usize, seed: u64)
                         -> super::ColorImage {
    colorize(&cablecar_like(width, height, seed), seed ^ 0xC0_11)
}

/// Named corpus used by benches/examples: the two paper stand-ins.
pub fn by_name(name: &str, width: usize, height: usize, seed: u64)
               -> Option<GrayImage> {
    match name {
        "lena" | "lena-like" | "portrait" => {
            Some(lena_like(width, height, seed))
        }
        "cablecar" | "cable-car" | "scene" => {
            Some(cablecar_like(width, height, seed))
        }
        _ => None,
    }
}

/// Color counterpart of [`by_name`].
pub fn color_by_name(name: &str, width: usize, height: usize, seed: u64)
                     -> Option<super::ColorImage> {
    match name {
        "lena" | "lena-like" | "portrait" => {
            Some(lena_like_rgb(width, height, seed))
        }
        "cablecar" | "cable-car" | "scene" => {
            Some(cablecar_like_rgb(width, height, seed))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(lena_like(64, 48, 9), lena_like(64, 48, 9));
        assert_ne!(lena_like(64, 48, 9), lena_like(64, 48, 10));
    }

    #[test]
    fn sizes_respected() {
        for (w, h) in [(200, 200), (97, 31), (8, 8)] {
            let img = lena_like(w, h, 1);
            assert_eq!((img.width, img.height), (w, h));
            let img = cablecar_like(w, h, 1);
            assert_eq!((img.width, img.height), (w, h));
        }
    }

    #[test]
    fn lena_has_natural_contrast() {
        let img = lena_like(128, 128, 5);
        let sd = img.stddev();
        assert!(sd > 15.0 && sd < 90.0, "stddev {sd}");
        assert!(img.mean() > 60.0 && img.mean() < 200.0);
    }

    #[test]
    fn both_scenes_have_substantial_ac_energy() {
        // total gradient magnitude as an edge-energy proxy: both stand-ins
        // must carry real mid/high-frequency content (this is what keeps
        // the Cordic-vs-DCT PSNR gap of Tables 3-4 visible), but far less
        // than white noise (~85 for uniform random pixels).
        let edge_energy = |img: &GrayImage| -> f64 {
            let mut e = 0.0;
            for y in 0..img.height {
                for x in 1..img.width {
                    e += (img.get(x, y) as f64 - img.get(x - 1, y) as f64)
                        .abs();
                }
            }
            e / img.pixels() as f64
        };
        let l = edge_energy(&lena_like(256, 256, 3));
        let c = edge_energy(&cablecar_like(256, 256, 3));
        for (name, e) in [("lena", l), ("cablecar", c)] {
            assert!(
                (4.0..60.0).contains(&e),
                "{name} edge energy {e:.2} outside natural-image band"
            );
        }
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("lena", 16, 16, 0).is_some());
        assert!(by_name("cable-car", 16, 16, 0).is_some());
        assert!(by_name("nonexistent", 16, 16, 0).is_none());
        assert!(color_by_name("lena", 16, 16, 0).is_some());
        assert!(color_by_name("nonexistent", 16, 16, 0).is_none());
    }

    #[test]
    fn color_scenes_are_actually_colored() {
        let img = lena_like_rgb(64, 64, 5);
        assert_eq!((img.width, img.height), (64, 64));
        // channels must differ somewhere (non-gray) ...
        let differs = img
            .data
            .chunks_exact(3)
            .any(|p| p[0] != p[1] || p[1] != p[2]);
        assert!(differs, "colorized image is gray");
        // ... but the luma plane stays close to the gray source (the
        // chroma fields mostly perturb Cb/Cr; RGB clamping near black /
        // white can shift individual luma samples)
        let (y, _, _) =
            crate::image::ycbcr::rgb_to_ycbcr(&img);
        let gray = lena_like(64, 64, 5);
        let mean_d = y
            .data
            .iter()
            .zip(&gray.data)
            .map(|(a, b)| (*a as i16 - *b as i16).unsigned_abs() as f64)
            .sum::<f64>()
            / y.pixels() as f64;
        assert!(mean_d < 2.0, "mean luma drift {mean_d}");
    }

    #[test]
    fn color_scenes_deterministic() {
        assert_eq!(
            cablecar_like_rgb(32, 24, 7),
            cablecar_like_rgb(32, 24, 7)
        );
        assert_ne!(
            cablecar_like_rgb(32, 24, 7),
            cablecar_like_rgb(32, 24, 8)
        );
    }

    #[test]
    fn plasma_spectrum_is_lowpass() {
        // column-mean absolute first difference should be much smaller than
        // pixel stddev for a 1/f field (smoothness check).
        let img = lena_like(128, 128, 77);
        let mut diff = 0.0;
        for y in 1..img.height {
            for x in 0..img.width {
                diff +=
                    (img.get(x, y) as f64 - img.get(x, y - 1) as f64).abs();
            }
        }
        diff /= (img.pixels() - img.width) as f64;
        assert!(
            diff < img.stddev() * 0.6,
            "mean |dy| {diff:.2} vs sd {:.2}",
            img.stddev()
        );
    }
}
