//! Image resampling: bilinear and area-average downscale.
//!
//! Used to produce the paper's exact size sweep from one master synthetic
//! image per scene (the paper resized Lena/Cable-car the same way).

use super::GrayImage;

/// Bilinear resample to (w, h).
pub fn bilinear(img: &GrayImage, w: usize, h: usize) -> GrayImage {
    assert!(w > 0 && h > 0);
    let mut out = GrayImage::new(w, h);
    let sx = img.width as f64 / w as f64;
    let sy = img.height as f64 / h as f64;
    for y in 0..h {
        // sample at pixel centers
        let fy = ((y as f64 + 0.5) * sy - 0.5)
            .clamp(0.0, img.height as f64 - 1.0);
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(img.height - 1);
        let wy = fy - y0 as f64;
        for x in 0..w {
            let fx = ((x as f64 + 0.5) * sx - 0.5)
                .clamp(0.0, img.width as f64 - 1.0);
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(img.width - 1);
            let wx = fx - x0 as f64;
            let v00 = img.get(x0, y0) as f64;
            let v01 = img.get(x1, y0) as f64;
            let v10 = img.get(x0, y1) as f64;
            let v11 = img.get(x1, y1) as f64;
            let v = v00 * (1.0 - wx) * (1.0 - wy)
                + v01 * wx * (1.0 - wy)
                + v10 * (1.0 - wx) * wy
                + v11 * wx * wy;
            out.set(x, y, v.round().clamp(0.0, 255.0) as u8);
        }
    }
    out
}

/// Area-average downscale (box filter) — better than bilinear when
/// shrinking by more than 2x (avoids aliasing in the size sweep).
pub fn area_downscale(img: &GrayImage, w: usize, h: usize) -> GrayImage {
    assert!(w > 0 && h > 0);
    assert!(w <= img.width && h <= img.height);
    let mut out = GrayImage::new(w, h);
    let sx = img.width as f64 / w as f64;
    let sy = img.height as f64 / h as f64;
    for y in 0..h {
        let y0 = (y as f64 * sy) as usize;
        let y1 = (((y + 1) as f64 * sy).ceil() as usize).min(img.height);
        for x in 0..w {
            let x0 = (x as f64 * sx) as usize;
            let x1 = (((x + 1) as f64 * sx).ceil() as usize).min(img.width);
            let mut sum = 0u64;
            for yy in y0..y1 {
                for xx in x0..x1 {
                    sum += img.get(xx, yy) as u64;
                }
            }
            let n = ((y1 - y0) * (x1 - x0)).max(1) as u64;
            out.set(x, y, ((sum + n / 2) / n) as u8);
        }
    }
    out
}

/// Resize choosing the right filter: area when shrinking >=2x in either
/// axis, bilinear otherwise.
pub fn resize(img: &GrayImage, w: usize, h: usize) -> GrayImage {
    if w * 2 <= img.width && h * 2 <= img.height {
        area_downscale(img, w, h)
    } else {
        bilinear(img, w, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    #[test]
    fn identity_resize_is_identity() {
        let img = synthetic::lena_like(32, 24, 1);
        let r = bilinear(&img, 32, 24);
        assert_eq!(img, r);
    }

    #[test]
    fn constant_image_stays_constant() {
        let img = GrayImage::from_vec(16, 16, vec![77; 256]).unwrap();
        for (w, h) in [(8, 8), (32, 32), (5, 11)] {
            let r = resize(&img, w, h);
            assert!(r.data.iter().all(|&v| v == 77), "{w}x{h}");
        }
    }

    #[test]
    fn upscale_dimensions() {
        let img = synthetic::lena_like(20, 20, 2);
        let r = bilinear(&img, 55, 33);
        assert_eq!((r.width, r.height), (55, 33));
    }

    #[test]
    fn downscale_preserves_mean() {
        let img = synthetic::lena_like(128, 128, 3);
        let r = area_downscale(&img, 32, 32);
        assert!((img.mean() - r.mean()).abs() < 2.0);
    }

    #[test]
    fn gradient_preserved_by_bilinear() {
        // horizontal ramp stays monotone
        let mut img = GrayImage::new(64, 8);
        for y in 0..8 {
            for x in 0..64 {
                img.set(x, y, (x * 4) as u8);
            }
        }
        let r = bilinear(&img, 32, 8);
        for x in 1..32 {
            assert!(r.get(x, 4) >= r.get(x - 1, 4));
        }
    }
}
