//! PGM (P2/P5) and PPM (P3/P6, luma-converted) codec.
//!
//! PGM is the interchange format of the classic image-processing test
//! suites (Marco Schmidt's database, which the paper used, distributes
//! PGM), so it is the primary on-disk format here.

use anyhow::{bail, Result};

use super::color::ColorImage;
use super::GrayImage;

/// Encode as binary PGM (P5).
pub fn encode(img: &GrayImage) -> Vec<u8> {
    let mut out = format!("P5\n{} {}\n255\n", img.width, img.height)
        .into_bytes();
    out.extend_from_slice(&img.data);
    out
}

/// Decode P2/P5 PGM or P3/P6 PPM (PPM converted to luma via BT.601).
pub fn decode(bytes: &[u8]) -> Result<GrayImage> {
    let mut t = Tokenizer { b: bytes, i: 0 };
    let magic = t.token()?;
    match magic.as_str() {
        "P5" | "P2" => {
            let (w, h) = (t.number()?, t.number()?);
            let maxval = t.number()?;
            if maxval == 0 || maxval > 255 {
                bail!("unsupported PGM maxval {maxval}");
            }
            let scale = 255.0 / maxval as f32;
            let data: Vec<u8> = if magic == "P5" {
                t.skip_single_whitespace();
                let need = w * h;
                let raw = t.rest();
                if raw.len() < need {
                    bail!("PGM truncated: {} < {}", raw.len(), need);
                }
                raw[..need]
                    .iter()
                    .map(|&v| ((v as f32) * scale).round() as u8)
                    .collect()
            } else {
                (0..w * h)
                    .map(|_| {
                        t.number()
                            .map(|v| ((v as f32) * scale).round() as u8)
                    })
                    .collect::<Result<_>>()?
            };
            GrayImage::from_vec(w, h, data)
        }
        "P6" | "P3" => {
            let (w, h) = (t.number()?, t.number()?);
            let maxval = t.number()?;
            if maxval == 0 || maxval > 255 {
                bail!("unsupported PPM maxval {maxval}");
            }
            let scale = 255.0 / maxval as f32;
            let mut rgb = Vec::with_capacity(w * h * 3);
            if magic == "P6" {
                t.skip_single_whitespace();
                let need = w * h * 3;
                let raw = t.rest();
                if raw.len() < need {
                    bail!("PPM truncated");
                }
                rgb.extend_from_slice(&raw[..need]);
            } else {
                for _ in 0..w * h * 3 {
                    rgb.push(t.number()? as u8);
                }
            }
            let data: Vec<u8> = rgb
                .chunks_exact(3)
                .map(|p| {
                    super::luma_f32(
                        p[0] as f32 * scale,
                        p[1] as f32 * scale,
                        p[2] as f32 * scale,
                    )
                })
                .collect();
            GrayImage::from_vec(w, h, data)
        }
        m => bail!("not a PGM/PPM file (magic {m:?})"),
    }
}

/// Encode interleaved RGB as binary PPM (P6).
pub fn encode_rgb(img: &ColorImage) -> Vec<u8> {
    let mut out = format!("P6\n{} {}\n255\n", img.width, img.height)
        .into_bytes();
    out.extend_from_slice(&img.data);
    out
}

/// Decode P3/P6 PPM keeping color; P2/P5 PGM is replicated into RGB.
pub fn decode_rgb(bytes: &[u8]) -> Result<ColorImage> {
    let mut t = Tokenizer { b: bytes, i: 0 };
    let magic = t.token()?;
    match magic.as_str() {
        "P6" | "P3" => {
            let (w, h) = (t.number()?, t.number()?);
            let maxval = t.number()?;
            if maxval == 0 || maxval > 255 {
                bail!("unsupported PPM maxval {maxval}");
            }
            let scale = 255.0 / maxval as f32;
            let need = w * h * 3;
            let mut rgb = Vec::with_capacity(need);
            if magic == "P6" {
                t.skip_single_whitespace();
                let raw = t.rest();
                if raw.len() < need {
                    bail!("PPM truncated");
                }
                rgb.extend(
                    raw[..need]
                        .iter()
                        .map(|&v| ((v as f32) * scale).round() as u8),
                );
            } else {
                for _ in 0..need {
                    rgb.push(
                        ((t.number()? as f32) * scale).round() as u8
                    );
                }
            }
            ColorImage::from_vec(w, h, rgb)
        }
        "P5" | "P2" => Ok(ColorImage::from_gray(&decode(bytes)?)),
        m => bail!("not a PPM/PGM file (magic {m:?})"),
    }
}

struct Tokenizer<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Tokenizer<'a> {
    /// Next whitespace-delimited token, skipping `#` comments.
    fn token(&mut self) -> Result<String> {
        loop {
            while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace()
            {
                self.i += 1;
            }
            if self.i < self.b.len() && self.b[self.i] == b'#' {
                while self.i < self.b.len() && self.b[self.i] != b'\n' {
                    self.i += 1;
                }
                continue;
            }
            break;
        }
        if self.i >= self.b.len() {
            bail!("unexpected end of PNM header");
        }
        let start = self.i;
        while self.i < self.b.len()
            && !self.b[self.i].is_ascii_whitespace()
        {
            self.i += 1;
        }
        Ok(String::from_utf8_lossy(&self.b[start..self.i]).into_owned())
    }

    fn number(&mut self) -> Result<usize> {
        let t = self.token()?;
        t.parse()
            .map_err(|e| anyhow::anyhow!("bad PNM number {t:?}: {e}"))
    }

    /// After maxval exactly one whitespace byte precedes binary data.
    fn skip_single_whitespace(&mut self) {
        if self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.i..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_p5() {
        let mut rng = Rng::new(1);
        let data: Vec<u8> = (0..35 * 17).map(|_| rng.next_u32() as u8).collect();
        let img = GrayImage::from_vec(35, 17, data).unwrap();
        let back = decode(&encode(&img)).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn decode_p2_ascii() {
        let txt = b"P2\n# comment\n3 2\n255\n0 128 255\n1 2 3\n";
        let img = decode(txt).unwrap();
        assert_eq!((img.width, img.height), (3, 2));
        assert_eq!(img.data, vec![0, 128, 255, 1, 2, 3]);
    }

    #[test]
    fn decode_p6_luma() {
        // one white pixel, one pure red pixel
        let mut b = b"P6\n2 1\n255\n".to_vec();
        b.extend_from_slice(&[255, 255, 255, 255, 0, 0]);
        let img = decode(&b).unwrap();
        assert_eq!(img.data[0], 255);
        assert_eq!(img.data[1], 76); // 0.299 * 255
    }

    #[test]
    fn maxval_rescaled() {
        let txt = b"P2\n1 1\n15\n15\n";
        let img = decode(txt).unwrap();
        assert_eq!(img.data[0], 255);
    }

    #[test]
    fn truncated_errors() {
        let mut b = b"P5\n4 4\n255\n".to_vec();
        b.extend_from_slice(&[0u8; 3]); // needs 16
        assert!(decode(&b).is_err());
    }

    #[test]
    fn bad_magic_errors() {
        assert!(decode(b"P9\n1 1\n255\n\0").is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn roundtrip_p6_color() {
        let mut rng = Rng::new(9);
        let data: Vec<u8> =
            (0..7 * 5 * 3).map(|_| rng.next_u32() as u8).collect();
        let img = ColorImage::from_vec(7, 5, data).unwrap();
        let back = decode_rgb(&encode_rgb(&img)).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn decode_rgb_from_gray_pgm_replicates() {
        let img = GrayImage::from_vec(2, 1, vec![3, 200]).unwrap();
        let c = decode_rgb(&encode(&img)).unwrap();
        assert_eq!(c.data, vec![3, 3, 3, 200, 200, 200]);
    }

    #[test]
    fn decode_rgb_truncated_errors() {
        let mut b = b"P6\n4 4\n255\n".to_vec();
        b.extend_from_slice(&[0u8; 10]); // needs 48
        assert!(decode_rgb(&b).is_err());
    }
}
