//! BT.601 full-range RGB ↔ YCbCr conversion and chroma subsampling —
//! the JPEG color model the color pipeline runs on.
//!
//! Conversion uses the ITU-R BT.601 luma weights at full (0..255) range,
//! exactly the JFIF convention, so the Y plane of an `R = G = B` image is
//! the grayscale image itself (the color-parity tests rely on this).
//! Chroma decimation is a box average whose window replicates the last
//! row/column at odd edges; interpolation back up is replication, so both
//! directions are well-defined on any image size.

use anyhow::{bail, Result};

use super::color::ColorImage;
use super::GrayImage;

/// Chroma subsampling mode (JPEG naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Subsampling {
    /// Full-resolution chroma.
    S444,
    /// Chroma halved horizontally.
    S422,
    /// Chroma halved horizontally and vertically.
    S420,
}

impl Subsampling {
    pub const ALL: [Subsampling; 3] =
        [Subsampling::S444, Subsampling::S422, Subsampling::S420];

    pub fn parse(s: &str) -> Option<Subsampling> {
        match s.trim() {
            "444" | "4:4:4" => Some(Subsampling::S444),
            "422" | "4:2:2" => Some(Subsampling::S422),
            "420" | "4:2:0" => Some(Subsampling::S420),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Subsampling::S444 => "4:4:4",
            Subsampling::S422 => "4:2:2",
            Subsampling::S420 => "4:2:0",
        }
    }

    /// File-name-safe tag ("444" / "422" / "420").
    pub fn tag(&self) -> &'static str {
        match self {
            Subsampling::S444 => "444",
            Subsampling::S422 => "422",
            Subsampling::S420 => "420",
        }
    }

    /// (horizontal, vertical) chroma decimation factors.
    pub fn factors(&self) -> (usize, usize) {
        match self {
            Subsampling::S444 => (1, 1),
            Subsampling::S422 => (2, 1),
            Subsampling::S420 => (2, 2),
        }
    }

    /// Chroma plane dimensions for a `w x h` luma plane (ceiling
    /// division: odd sizes keep their partial edge sample).
    pub fn chroma_dims(&self, w: usize, h: usize) -> (usize, usize) {
        let (fx, fy) = self.factors();
        (w.div_ceil(fx), h.div_ceil(fy))
    }
}

#[inline]
fn clamp_u8(v: f32) -> u8 {
    v.clamp(0.0, 255.0).round() as u8
}

/// Split an RGB image into full-resolution Y/Cb/Cr planes (BT.601
/// full-range, the JFIF convention). For `R = G = B` inputs the Y plane
/// equals the input channel and Cb = Cr = 128 exactly.
pub fn rgb_to_ycbcr(
    img: &ColorImage,
) -> (GrayImage, GrayImage, GrayImage) {
    let n = img.pixels();
    let mut y = Vec::with_capacity(n);
    let mut cb = Vec::with_capacity(n);
    let mut cr = Vec::with_capacity(n);
    for p in img.data.chunks_exact(3) {
        let (r, g, b) = (p[0] as f32, p[1] as f32, p[2] as f32);
        y.push(clamp_u8(0.299 * r + 0.587 * g + 0.114 * b));
        cb.push(clamp_u8(
            128.0 - 0.168_736 * r - 0.331_264 * g + 0.5 * b,
        ));
        cr.push(clamp_u8(
            128.0 + 0.5 * r - 0.418_688 * g - 0.081_312 * b,
        ));
    }
    let plane = |data| GrayImage {
        width: img.width,
        height: img.height,
        data,
    };
    (plane(y), plane(cb), plane(cr))
}

/// Merge full-resolution Y/Cb/Cr planes back into an RGB image.
pub fn ycbcr_to_rgb(
    y: &GrayImage,
    cb: &GrayImage,
    cr: &GrayImage,
) -> Result<ColorImage> {
    if (y.width, y.height) != (cb.width, cb.height)
        || (y.width, y.height) != (cr.width, cr.height)
    {
        bail!(
            "YCbCr plane sizes differ: Y {}x{}, Cb {}x{}, Cr {}x{}",
            y.width,
            y.height,
            cb.width,
            cb.height,
            cr.width,
            cr.height
        );
    }
    let mut data = Vec::with_capacity(y.pixels() * 3);
    for i in 0..y.pixels() {
        let yy = y.data[i] as f32;
        let u = cb.data[i] as f32 - 128.0;
        let v = cr.data[i] as f32 - 128.0;
        data.push(clamp_u8(yy + 1.402 * v));
        data.push(clamp_u8(yy - 0.344_136 * u - 0.714_136 * v));
        data.push(clamp_u8(yy + 1.772 * u));
    }
    ColorImage::from_vec(y.width, y.height, data)
}

/// Box-average decimation by the mode's factors. Windows that overhang an
/// odd edge replicate the last row/column, so every output pixel averages
/// a full `fx x fy` window and constant planes stay exactly constant.
pub fn downsample(plane: &GrayImage, mode: Subsampling) -> GrayImage {
    let (fx, fy) = mode.factors();
    if fx == 1 && fy == 1 {
        return plane.clone();
    }
    let (cw, ch) = mode.chroma_dims(plane.width, plane.height);
    let window = (fx * fy) as u32;
    let mut out = GrayImage::new(cw, ch);
    for oy in 0..ch {
        for ox in 0..cw {
            let mut sum = 0u32;
            for dy in 0..fy {
                let sy = (oy * fy + dy).min(plane.height - 1);
                for dx in 0..fx {
                    let sx = (ox * fx + dx).min(plane.width - 1);
                    sum += plane.get(sx, sy) as u32;
                }
            }
            out.set(ox, oy, ((sum + window / 2) / window) as u8);
        }
    }
    out
}

/// Replicate a decimated chroma plane back up to `w x h` luma resolution
/// (nearest-neighbor; edge samples replicate, mirroring [`downsample`]).
pub fn upsample(
    plane: &GrayImage,
    mode: Subsampling,
    w: usize,
    h: usize,
) -> GrayImage {
    let (fx, fy) = mode.factors();
    let mut out = GrayImage::new(w, h);
    for y in 0..h {
        let sy = (y / fy).min(plane.height - 1);
        for x in 0..w {
            let sx = (x / fx).min(plane.width - 1);
            out.set(x, y, plane.get(sx, sy));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsampling_parse_and_dims() {
        assert_eq!(Subsampling::parse("4:2:0"), Some(Subsampling::S420));
        assert_eq!(Subsampling::parse("422"), Some(Subsampling::S422));
        assert_eq!(Subsampling::parse("x"), None);
        assert_eq!(Subsampling::S420.chroma_dims(33, 21), (17, 11));
        assert_eq!(Subsampling::S422.chroma_dims(33, 21), (17, 21));
        assert_eq!(Subsampling::S444.chroma_dims(33, 21), (33, 21));
    }

    #[test]
    fn gray_input_maps_to_neutral_chroma() {
        let g = GrayImage::from_vec(2, 1, vec![0, 201]).unwrap();
        let (y, cb, cr) = rgb_to_ycbcr(&ColorImage::from_gray(&g));
        assert_eq!(y.data, g.data);
        assert!(cb.data.iter().all(|&v| v == 128), "{:?}", cb.data);
        assert!(cr.data.iter().all(|&v| v == 128), "{:?}", cr.data);
    }

    #[test]
    fn primary_colors_roundtrip_closely() {
        let img = ColorImage::from_vec(
            4,
            1,
            vec![255, 0, 0, 0, 255, 0, 0, 0, 255, 17, 130, 244],
        )
        .unwrap();
        let (y, cb, cr) = rgb_to_ycbcr(&img);
        let back = ycbcr_to_rgb(&y, &cb, &cr).unwrap();
        for (a, b) in img.data.iter().zip(&back.data) {
            assert!(
                (*a as i16 - *b as i16).abs() <= 2,
                "channel {a} -> {b}"
            );
        }
    }

    #[test]
    fn plane_size_mismatch_rejected() {
        let a = GrayImage::new(4, 4);
        let b = GrayImage::new(2, 2);
        assert!(ycbcr_to_rgb(&a, &b, &a).is_err());
    }

    #[test]
    fn downsample_constant_is_exact() {
        let p = GrayImage::from_vec(5, 3, vec![77; 15]).unwrap();
        for mode in Subsampling::ALL {
            let d = downsample(&p, mode);
            let (cw, ch) = mode.chroma_dims(5, 3);
            assert_eq!((d.width, d.height), (cw, ch));
            assert!(d.data.iter().all(|&v| v == 77));
            let u = upsample(&d, mode, 5, 3);
            assert_eq!(u, p);
        }
    }

    #[test]
    fn downsample_averages_box() {
        let p =
            GrayImage::from_vec(2, 2, vec![10, 20, 30, 40]).unwrap();
        let d = downsample(&p, Subsampling::S420);
        assert_eq!((d.width, d.height), (1, 1));
        assert_eq!(d.data[0], 25);
        let d = downsample(&p, Subsampling::S422);
        assert_eq!((d.width, d.height), (1, 2));
        assert_eq!(d.data, vec![15, 35]);
    }

    #[test]
    fn odd_edge_replicates() {
        // 3 wide: last 4:2:0 window covers column 2 twice
        let p = GrayImage::from_vec(3, 1, vec![0, 100, 50]).unwrap();
        let d = downsample(&p, Subsampling::S422);
        assert_eq!(d.data.len(), 2);
        assert_eq!(d.data[0], 50); // (0 + 100 + 1) / 2
        assert_eq!(d.data[1], 50); // (50 + 50 + 1) / 2
    }
}
