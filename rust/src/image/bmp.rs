//! BMP codec: 8-bit grayscale palette BMPs (what the paper-era Windows
//! tooling produced) plus 24-bit decode with luma conversion.

use anyhow::{bail, Result};

use super::GrayImage;

fn u16le(b: &[u8], off: usize) -> u32 {
    u16::from_le_bytes([b[off], b[off + 1]]) as u32
}

fn u32le(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn i32le(b: &[u8], off: usize) -> i32 {
    i32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Encode as 8-bit palettized grayscale BMP (bottom-up, 4-byte row pad).
pub fn encode(img: &GrayImage) -> Vec<u8> {
    let row = img.width.div_ceil(4) * 4;
    let palette_len = 256 * 4;
    let data_off = 14 + 40 + palette_len;
    let file_len = data_off + row * img.height;
    let mut out = Vec::with_capacity(file_len);
    // BITMAPFILEHEADER
    out.extend_from_slice(b"BM");
    out.extend_from_slice(&(file_len as u32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&(data_off as u32).to_le_bytes());
    // BITMAPINFOHEADER
    out.extend_from_slice(&40u32.to_le_bytes());
    out.extend_from_slice(&(img.width as i32).to_le_bytes());
    out.extend_from_slice(&(img.height as i32).to_le_bytes());
    out.extend_from_slice(&1u16.to_le_bytes());
    out.extend_from_slice(&8u16.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // BI_RGB
    out.extend_from_slice(&((row * img.height) as u32).to_le_bytes());
    out.extend_from_slice(&2835u32.to_le_bytes()); // 72 dpi
    out.extend_from_slice(&2835u32.to_le_bytes());
    out.extend_from_slice(&256u32.to_le_bytes());
    out.extend_from_slice(&256u32.to_le_bytes());
    // grayscale palette
    for v in 0..=255u8 {
        out.extend_from_slice(&[v, v, v, 0]);
    }
    // pixel rows, bottom-up
    for y in (0..img.height).rev() {
        let start = y * img.width;
        out.extend_from_slice(&img.data[start..start + img.width]);
        out.resize(out.len() + (row - img.width), 0);
    }
    out
}

/// Decode 8-bit palettized or 24-bit uncompressed BMP to grayscale.
pub fn decode(bytes: &[u8]) -> Result<GrayImage> {
    if bytes.len() < 54 || &bytes[0..2] != b"BM" {
        bail!("not a BMP file");
    }
    let data_off = u32le(bytes, 10) as usize;
    let header_size = u32le(bytes, 14) as usize;
    if header_size < 40 {
        bail!("unsupported BMP header size {header_size}");
    }
    let width = i32le(bytes, 18);
    let height_raw = i32le(bytes, 22);
    let bpp = u16le(bytes, 28);
    let compression = u32le(bytes, 30);
    if compression != 0 {
        bail!("compressed BMP (type {compression}) unsupported");
    }
    if width <= 0 || height_raw == 0 {
        bail!("bad BMP dimensions {width}x{height_raw}");
    }
    let width = width as usize;
    let top_down = height_raw < 0;
    let height = height_raw.unsigned_abs() as usize;

    let mut img = GrayImage::new(width, height);
    match bpp {
        8 => {
            // palette: 4 bytes per entry, right after the info header
            let palette_off = 14 + header_size;
            let ncolors = {
                let n = u32le(bytes, 46) as usize;
                if n == 0 { 256 } else { n }
            };
            if palette_off + ncolors * 4 > data_off {
                bail!("BMP palette overruns pixel data");
            }
            let mut luma = [0u8; 256];
            for (i, l) in luma.iter_mut().enumerate().take(ncolors) {
                let e = palette_off + i * 4;
                let (b, g, r) = (
                    bytes[e] as f32,
                    bytes[e + 1] as f32,
                    bytes[e + 2] as f32,
                );
                *l = (0.299 * r + 0.587 * g + 0.114 * b).round() as u8;
            }
            let row = width.div_ceil(4) * 4;
            if data_off + row * height > bytes.len() {
                bail!("BMP pixel data truncated");
            }
            for dy in 0..height {
                let sy = if top_down { dy } else { height - 1 - dy };
                let src = data_off + sy * row;
                for x in 0..width {
                    img.data[dy * width + x] = luma[bytes[src + x] as usize];
                }
            }
        }
        24 => {
            let row = (width * 3).div_ceil(4) * 4;
            if data_off + row * height > bytes.len() {
                bail!("BMP pixel data truncated");
            }
            for dy in 0..height {
                let sy = if top_down { dy } else { height - 1 - dy };
                let src = data_off + sy * row;
                for x in 0..width {
                    let e = src + x * 3;
                    let (b, g, r) = (
                        bytes[e] as f32,
                        bytes[e + 1] as f32,
                        bytes[e + 2] as f32,
                    );
                    img.data[dy * width + x] =
                        (0.299 * r + 0.587 * g + 0.114 * b).round() as u8;
                }
            }
        }
        _ => bail!("unsupported BMP bit depth {bpp}"),
    }
    Ok(img)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_8bit() {
        let mut rng = Rng::new(2);
        // width 30 exercises row padding (30 % 4 != 0)
        let data: Vec<u8> = (0..30 * 11).map(|_| rng.next_u32() as u8).collect();
        let img = GrayImage::from_vec(30, 11, data).unwrap();
        let back = decode(&encode(&img)).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(b"not a bmp at all").is_err());
        assert!(decode(&[]).is_err());
    }

    #[test]
    fn rejects_truncated_pixels() {
        let img = GrayImage::new(16, 16);
        let mut bytes = encode(&img);
        bytes.truncate(bytes.len() - 10);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn header_fields() {
        let img = GrayImage::new(5, 3);
        let b = encode(&img);
        assert_eq!(&b[0..2], b"BM");
        assert_eq!(u16le(&b, 28), 8); // bpp
        assert_eq!(i32le(&b, 18), 5);
        assert_eq!(i32le(&b, 22), 3);
    }
}
