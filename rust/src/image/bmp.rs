//! BMP codec: 8-bit grayscale palette BMPs (what the paper-era Windows
//! tooling produced), 24-bit decode with luma conversion, and 24-bit
//! color encode/decode for the color pipeline.

use anyhow::{bail, Result};

use super::color::ColorImage;
use super::GrayImage;

fn u16le(b: &[u8], off: usize) -> u32 {
    u16::from_le_bytes([b[off], b[off + 1]]) as u32
}

fn u32le(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn i32le(b: &[u8], off: usize) -> i32 {
    i32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Encode as 8-bit palettized grayscale BMP (bottom-up, 4-byte row pad).
pub fn encode(img: &GrayImage) -> Vec<u8> {
    let row = img.width.div_ceil(4) * 4;
    let palette_len = 256 * 4;
    let data_off = 14 + 40 + palette_len;
    let file_len = data_off + row * img.height;
    let mut out = Vec::with_capacity(file_len);
    // BITMAPFILEHEADER
    out.extend_from_slice(b"BM");
    out.extend_from_slice(&(file_len as u32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&(data_off as u32).to_le_bytes());
    // BITMAPINFOHEADER
    out.extend_from_slice(&40u32.to_le_bytes());
    out.extend_from_slice(&(img.width as i32).to_le_bytes());
    out.extend_from_slice(&(img.height as i32).to_le_bytes());
    out.extend_from_slice(&1u16.to_le_bytes());
    out.extend_from_slice(&8u16.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // BI_RGB
    out.extend_from_slice(&((row * img.height) as u32).to_le_bytes());
    out.extend_from_slice(&2835u32.to_le_bytes()); // 72 dpi
    out.extend_from_slice(&2835u32.to_le_bytes());
    out.extend_from_slice(&256u32.to_le_bytes());
    out.extend_from_slice(&256u32.to_le_bytes());
    // grayscale palette
    for v in 0..=255u8 {
        out.extend_from_slice(&[v, v, v, 0]);
    }
    // pixel rows, bottom-up
    for y in (0..img.height).rev() {
        let start = y * img.width;
        out.extend_from_slice(&img.data[start..start + img.width]);
        out.resize(out.len() + (row - img.width), 0);
    }
    out
}

/// Encode as 24-bit uncompressed BMP (bottom-up, BGR, 4-byte row pad).
pub fn encode_rgb(img: &ColorImage) -> Vec<u8> {
    let row = (img.width * 3).div_ceil(4) * 4;
    let data_off = 14 + 40;
    let file_len = data_off + row * img.height;
    let mut out = Vec::with_capacity(file_len);
    // BITMAPFILEHEADER
    out.extend_from_slice(b"BM");
    out.extend_from_slice(&(file_len as u32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&(data_off as u32).to_le_bytes());
    // BITMAPINFOHEADER
    out.extend_from_slice(&40u32.to_le_bytes());
    out.extend_from_slice(&(img.width as i32).to_le_bytes());
    out.extend_from_slice(&(img.height as i32).to_le_bytes());
    out.extend_from_slice(&1u16.to_le_bytes());
    out.extend_from_slice(&24u16.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // BI_RGB
    out.extend_from_slice(&((row * img.height) as u32).to_le_bytes());
    out.extend_from_slice(&2835u32.to_le_bytes()); // 72 dpi
    out.extend_from_slice(&2835u32.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    // pixel rows, bottom-up, BGR order
    for y in (0..img.height).rev() {
        for x in 0..img.width {
            let [r, g, b] = img.get(x, y);
            out.extend_from_slice(&[b, g, r]);
        }
        out.resize(out.len() + (row - img.width * 3), 0);
    }
    out
}

/// Decode 24-bit (kept in color) or 8-bit palettized (palette colors
/// preserved) uncompressed BMP to RGB.
pub fn decode_rgb(bytes: &[u8]) -> Result<ColorImage> {
    let h = parse_header(bytes)?;
    let mut img = ColorImage::new(h.width, h.height);
    match h.bpp {
        8 => {
            let (palette, row) = palette_and_row(bytes, &h)?;
            for dy in 0..h.height {
                let sy = h.src_row(dy);
                let src = h.data_off + sy * row;
                for x in 0..h.width {
                    img.set(x, dy, palette[bytes[src + x] as usize]);
                }
            }
        }
        24 => {
            let row = rgb24_row(bytes, &h)?;
            for dy in 0..h.height {
                let sy = h.src_row(dy);
                let src = h.data_off + sy * row;
                for x in 0..h.width {
                    let e = src + x * 3;
                    img.set(
                        x,
                        dy,
                        [bytes[e + 2], bytes[e + 1], bytes[e]],
                    );
                }
            }
        }
        bpp => bail!("unsupported BMP bit depth {bpp}"),
    }
    Ok(img)
}

/// Parsed BMP header fields shared by the gray and color decoders.
struct BmpHeader {
    width: usize,
    height: usize,
    bpp: u32,
    data_off: usize,
    header_size: usize,
    top_down: bool,
}

impl BmpHeader {
    /// Source row index for destination row `dy` (BMPs are usually
    /// bottom-up).
    fn src_row(&self, dy: usize) -> usize {
        if self.top_down {
            dy
        } else {
            self.height - 1 - dy
        }
    }
}

fn parse_header(bytes: &[u8]) -> Result<BmpHeader> {
    if bytes.len() < 54 || &bytes[0..2] != b"BM" {
        bail!("not a BMP file");
    }
    let data_off = u32le(bytes, 10) as usize;
    let header_size = u32le(bytes, 14) as usize;
    if header_size < 40 {
        bail!("unsupported BMP header size {header_size}");
    }
    let width = i32le(bytes, 18);
    let height_raw = i32le(bytes, 22);
    let bpp = u16le(bytes, 28);
    let compression = u32le(bytes, 30);
    if compression != 0 {
        bail!("compressed BMP (type {compression}) unsupported");
    }
    if width <= 0 || height_raw == 0 {
        bail!("bad BMP dimensions {width}x{height_raw}");
    }
    Ok(BmpHeader {
        width: width as usize,
        height: height_raw.unsigned_abs() as usize,
        bpp,
        data_off,
        header_size,
        top_down: height_raw < 0,
    })
}

/// Read the 8-bit palette (as RGB triples) and validate the row stride.
fn palette_and_row(
    bytes: &[u8],
    h: &BmpHeader,
) -> Result<([[u8; 3]; 256], usize)> {
    let palette_off = 14 + h.header_size;
    let ncolors = {
        let n = u32le(bytes, 46) as usize;
        if n == 0 {
            256
        } else {
            n
        }
    };
    if palette_off + ncolors * 4 > h.data_off {
        bail!("BMP palette overruns pixel data");
    }
    let mut palette = [[0u8; 3]; 256];
    for (i, p) in palette.iter_mut().enumerate().take(ncolors) {
        let e = palette_off + i * 4;
        *p = [bytes[e + 2], bytes[e + 1], bytes[e]];
    }
    let row = h.width.div_ceil(4) * 4;
    if h.data_off + row * h.height > bytes.len() {
        bail!("BMP pixel data truncated");
    }
    Ok((palette, row))
}

/// Validate the 24-bit row stride against the file size.
fn rgb24_row(bytes: &[u8], h: &BmpHeader) -> Result<usize> {
    let row = (h.width * 3).div_ceil(4) * 4;
    if h.data_off + row * h.height > bytes.len() {
        bail!("BMP pixel data truncated");
    }
    Ok(row)
}

/// Decode 8-bit palettized or 24-bit uncompressed BMP to grayscale.
pub fn decode(bytes: &[u8]) -> Result<GrayImage> {
    let h = parse_header(bytes)?;
    let mut img = GrayImage::new(h.width, h.height);
    let luma = |r: u8, g: u8, b: u8| {
        super::luma_f32(r as f32, g as f32, b as f32)
    };
    match h.bpp {
        8 => {
            let (palette, row) = palette_and_row(bytes, &h)?;
            let mut lut = [0u8; 256];
            for (l, p) in lut.iter_mut().zip(palette.iter()) {
                *l = luma(p[0], p[1], p[2]);
            }
            for dy in 0..h.height {
                let sy = h.src_row(dy);
                let src = h.data_off + sy * row;
                for x in 0..h.width {
                    img.data[dy * h.width + x] =
                        lut[bytes[src + x] as usize];
                }
            }
        }
        24 => {
            let row = rgb24_row(bytes, &h)?;
            for dy in 0..h.height {
                let sy = h.src_row(dy);
                let src = h.data_off + sy * row;
                for x in 0..h.width {
                    let e = src + x * 3;
                    img.data[dy * h.width + x] =
                        luma(bytes[e + 2], bytes[e + 1], bytes[e]);
                }
            }
        }
        bpp => bail!("unsupported BMP bit depth {bpp}"),
    }
    Ok(img)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_8bit() {
        let mut rng = Rng::new(2);
        // width 30 exercises row padding (30 % 4 != 0)
        let data: Vec<u8> = (0..30 * 11).map(|_| rng.next_u32() as u8).collect();
        let img = GrayImage::from_vec(30, 11, data).unwrap();
        let back = decode(&encode(&img)).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(b"not a bmp at all").is_err());
        assert!(decode(&[]).is_err());
    }

    #[test]
    fn rejects_truncated_pixels() {
        let img = GrayImage::new(16, 16);
        let mut bytes = encode(&img);
        bytes.truncate(bytes.len() - 10);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn header_fields() {
        let img = GrayImage::new(5, 3);
        let b = encode(&img);
        assert_eq!(&b[0..2], b"BM");
        assert_eq!(u16le(&b, 28), 8); // bpp
        assert_eq!(i32le(&b, 18), 5);
        assert_eq!(i32le(&b, 22), 3);
    }

    #[test]
    fn roundtrip_24bit_color() {
        let mut rng = Rng::new(11);
        // width 7 exercises 24-bit row padding (21 % 4 != 0)
        let data: Vec<u8> =
            (0..7 * 5 * 3).map(|_| rng.next_u32() as u8).collect();
        let img = ColorImage::from_vec(7, 5, data).unwrap();
        let back = decode_rgb(&encode_rgb(&img)).unwrap();
        assert_eq!(img, back);
        assert_eq!(u16le(&encode_rgb(&img), 28), 24);
    }

    #[test]
    fn color_decode_of_gray_bmp_replicates_palette() {
        let img = GrayImage::from_vec(2, 2, vec![0, 80, 160, 255]).unwrap();
        let c = decode_rgb(&encode(&img)).unwrap();
        assert_eq!(c.to_gray(), img);
        assert_eq!(c.get(1, 0), [80, 80, 80]);
    }

    #[test]
    fn gray_decode_of_color_bmp_is_luma() {
        let img = ColorImage::from_vec(1, 1, vec![255, 0, 0]).unwrap();
        let g = decode(&encode_rgb(&img)).unwrap();
        assert_eq!(g.data[0], 76); // 0.299 * 255
    }

    #[test]
    fn decode_rgb_rejects_truncated() {
        let img = ColorImage::new(8, 8);
        let mut bytes = encode_rgb(&img);
        bytes.truncate(bytes.len() - 10);
        assert!(decode_rgb(&bytes).is_err());
        assert!(decode_rgb(b"junk").is_err());
    }
}
