//! PNG codec for 8-bit grayscale and RGB: encoders (filter 0/Sub/Up
//! heuristic + zlib via flate2) and decoders (all five filter types;
//! grayscale decode converts color to luma, color decode keeps RGB).
//! CRCs via crc32fast.

use std::io::{Read, Write};

use anyhow::{bail, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;

use super::color::ColorImage;
use super::GrayImage;

const MAGIC: [u8; 8] = [0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1A, b'\n'];

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], body: &[u8]) {
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(tag);
    out.extend_from_slice(body);
    let mut h = crc32fast::Hasher::new();
    h.update(tag);
    h.update(body);
    out.extend_from_slice(&h.finalize().to_be_bytes());
}

/// Per-row filter selection (None / Sub / Up by minimum absolute residual
/// sum, the libpng heuristic) over `h` rows of `stride` bytes with
/// `bpp`-byte pixels; returns filter-byte-prefixed scanlines.
fn filter_scanlines(
    data: &[u8],
    stride: usize,
    h: usize,
    bpp: usize,
) -> Vec<u8> {
    let mut raw = Vec::with_capacity(h * (stride + 1));
    let zero_row = vec![0u8; stride];
    for y in 0..h {
        let row = &data[y * stride..(y + 1) * stride];
        let prev: &[u8] = if y == 0 {
            &zero_row
        } else {
            &data[(y - 1) * stride..y * stride]
        };
        // candidate filters
        let none_cost: u64 =
            row.iter().map(|&v| (v as i16).unsigned_abs() as u64).sum();
        let sub_cost: u64 = row
            .iter()
            .enumerate()
            .map(|(x, &v)| {
                let left = if x < bpp { 0 } else { row[x - bpp] };
                (v.wrapping_sub(left) as i8).unsigned_abs() as u64
            })
            .sum();
        let up_cost: u64 = row
            .iter()
            .zip(prev)
            .map(|(&v, &u)| (v.wrapping_sub(u) as i8).unsigned_abs() as u64)
            .sum();
        if sub_cost <= none_cost && sub_cost <= up_cost {
            raw.push(1u8);
            for x in 0..stride {
                let left = if x < bpp { 0 } else { row[x - bpp] };
                raw.push(row[x].wrapping_sub(left));
            }
        } else if up_cost <= none_cost {
            raw.push(2u8);
            for x in 0..stride {
                raw.push(row[x].wrapping_sub(prev[x]));
            }
        } else {
            raw.push(0u8);
            raw.extend_from_slice(row);
        }
    }
    raw
}

/// Assemble the PNG container around filtered scanlines.
fn write_container(
    w: usize,
    h: usize,
    color_type: u8,
    raw: &[u8],
) -> Result<Vec<u8>> {
    let mut z = ZlibEncoder::new(Vec::new(), Compression::new(6));
    z.write_all(raw)?;
    let compressed = z.finish()?;

    let mut out = Vec::with_capacity(compressed.len() + 64);
    out.extend_from_slice(&MAGIC);
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&(w as u32).to_be_bytes());
    ihdr.extend_from_slice(&(h as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, color_type, 0, 0, 0]); // no interlace
    chunk(&mut out, b"IHDR", &ihdr);
    chunk(&mut out, b"IDAT", &compressed);
    chunk(&mut out, b"IEND", &[]);
    Ok(out)
}

/// Encode as 8-bit grayscale PNG.
pub fn encode(img: &GrayImage) -> Result<Vec<u8>> {
    let (w, h) = (img.width, img.height);
    if w == 0 || h == 0 {
        bail!("cannot encode empty image");
    }
    let raw = filter_scanlines(&img.data, w, h, 1);
    write_container(w, h, 0, &raw)
}

/// Encode as 8-bit RGB (color type 2) PNG.
pub fn encode_rgb(img: &ColorImage) -> Result<Vec<u8>> {
    let (w, h) = (img.width, img.height);
    if w == 0 || h == 0 {
        bail!("cannot encode empty image");
    }
    let raw = filter_scanlines(&img.data, w * 3, h, 3);
    write_container(w, h, 2, &raw)
}

#[inline]
fn paeth(a: i16, b: i16, c: i16) -> u8 {
    let p = a + b - c;
    let (pa, pb, pc) = ((p - a).abs(), (p - b).abs(), (p - c).abs());
    if pa <= pb && pa <= pc {
        a as u8
    } else if pb <= pc {
        b as u8
    } else {
        c as u8
    }
}

/// Unfiltered pixel data of a decoded PNG, channels interleaved.
struct RawPng {
    w: usize,
    h: usize,
    channels: usize,
    pix: Vec<u8>,
}

/// Decode an 8-bit grayscale / RGB / RGBA / gray+alpha PNG
/// (non-interlaced, non-paletted), converting color to luma.
pub fn decode(bytes: &[u8]) -> Result<GrayImage> {
    let raw = decode_raw(bytes)?;
    let data: Vec<u8> = match raw.channels {
        1 => raw.pix,
        2 => raw.pix.chunks_exact(2).map(|p| p[0]).collect(),
        n => raw
            .pix
            .chunks_exact(n)
            .map(|p| {
                super::luma_f32(
                    p[0] as f32,
                    p[1] as f32,
                    p[2] as f32,
                )
            })
            .collect(),
    };
    GrayImage::from_vec(raw.w, raw.h, data)
}

/// Decode a PNG keeping color: RGB[A] stays RGB (alpha dropped),
/// grayscale[+alpha] is replicated into all three channels.
pub fn decode_rgb(bytes: &[u8]) -> Result<ColorImage> {
    let raw = decode_raw(bytes)?;
    let mut data = Vec::with_capacity(raw.w * raw.h * 3);
    for p in raw.pix.chunks_exact(raw.channels) {
        match raw.channels {
            1 | 2 => data.extend_from_slice(&[p[0], p[0], p[0]]),
            _ => data.extend_from_slice(&p[0..3]),
        }
    }
    ColorImage::from_vec(raw.w, raw.h, data)
}

fn decode_raw(bytes: &[u8]) -> Result<RawPng> {
    if bytes.len() < 8 || bytes[..8] != MAGIC {
        bail!("not a PNG file");
    }
    let mut i = 8usize;
    let mut w = 0usize;
    let mut h = 0usize;
    let mut channels = 0usize;
    let mut idat: Vec<u8> = Vec::new();
    let mut seen_ihdr = false;
    while i + 8 <= bytes.len() {
        let len = u32::from_be_bytes(bytes[i..i + 4].try_into()?) as usize;
        let tag = &bytes[i + 4..i + 8];
        let body_start = i + 8;
        let body_end = body_start + len;
        if body_end + 4 > bytes.len() {
            bail!("PNG chunk overruns file");
        }
        let body = &bytes[body_start..body_end];
        // verify CRC
        let mut hsh = crc32fast::Hasher::new();
        hsh.update(tag);
        hsh.update(body);
        let want =
            u32::from_be_bytes(bytes[body_end..body_end + 4].try_into()?);
        if hsh.finalize() != want {
            bail!("PNG chunk CRC mismatch in {:?}", String::from_utf8_lossy(tag));
        }
        match tag {
            b"IHDR" => {
                if len != 13 {
                    bail!("bad IHDR length");
                }
                w = u32::from_be_bytes(body[0..4].try_into()?) as usize;
                h = u32::from_be_bytes(body[4..8].try_into()?) as usize;
                let bit_depth = body[8];
                let color_type = body[9];
                let interlace = body[12];
                if bit_depth != 8 {
                    bail!("unsupported PNG bit depth {bit_depth}");
                }
                if interlace != 0 {
                    bail!("interlaced PNG unsupported");
                }
                channels = match color_type {
                    0 => 1,
                    2 => 3,
                    4 => 2,
                    6 => 4,
                    t => bail!("unsupported PNG color type {t}"),
                };
                seen_ihdr = true;
            }
            b"IDAT" => idat.extend_from_slice(body),
            b"IEND" => break,
            _ => {} // ancillary chunks ignored
        }
        i = body_end + 4;
    }
    if !seen_ihdr || w == 0 || h == 0 {
        bail!("PNG missing IHDR / zero dimensions");
    }
    let mut raw = Vec::new();
    ZlibDecoder::new(&idat[..]).read_to_end(&mut raw)?;
    let stride = w * channels;
    if raw.len() != h * (stride + 1) {
        bail!(
            "PNG data size {} != expected {}",
            raw.len(),
            h * (stride + 1)
        );
    }
    // unfilter in place into `pix`
    let mut pix = vec![0u8; h * stride];
    for y in 0..h {
        let ftype = raw[y * (stride + 1)];
        let src = &raw[y * (stride + 1) + 1..(y + 1) * (stride + 1)];
        for x in 0..stride {
            let left = if x >= channels {
                pix[y * stride + x - channels]
            } else {
                0
            };
            let up = if y > 0 { pix[(y - 1) * stride + x] } else { 0 };
            let ul = if y > 0 && x >= channels {
                pix[(y - 1) * stride + x - channels]
            } else {
                0
            };
            let rec = match ftype {
                0 => src[x],
                1 => src[x].wrapping_add(left),
                2 => src[x].wrapping_add(up),
                3 => src[x]
                    .wrapping_add(((left as u16 + up as u16) / 2) as u8),
                4 => src[x].wrapping_add(paeth(
                    left as i16,
                    up as i16,
                    ul as i16,
                )),
                t => bail!("bad PNG filter type {t}"),
            };
            pix[y * stride + x] = rec;
        }
    }
    Ok(RawPng {
        w,
        h,
        channels,
        pix,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(3);
        let data: Vec<u8> = (0..41 * 23).map(|_| rng.next_u32() as u8).collect();
        let img = GrayImage::from_vec(41, 23, data).unwrap();
        let back = decode(&encode(&img).unwrap()).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn roundtrip_natural() {
        // natural image exercises Sub/Up filter selection
        let img = synthetic::lena_like(96, 80, 7);
        let enc = encode(&img).unwrap();
        let back = decode(&enc).unwrap();
        assert_eq!(img, back);
        // natural content must compress below raw size
        assert!(enc.len() < img.pixels());
    }

    #[test]
    fn crc_checked() {
        let img = GrayImage::new(8, 8);
        let mut enc = encode(&img).unwrap();
        let n = enc.len();
        enc[n - 8] ^= 0xFF; // corrupt IEND CRC region (or IDAT body end)
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(b"hello world").is_err());
        assert!(decode(&MAGIC).is_err());
    }

    #[test]
    fn constant_image_compresses_hard() {
        let img = GrayImage::from_vec(64, 64, vec![128; 64 * 64]).unwrap();
        let enc = encode(&img).unwrap();
        assert!(enc.len() < 200, "constant image -> tiny PNG, got {}",
                enc.len());
    }

    #[test]
    fn roundtrip_rgb() {
        let mut rng = Rng::new(21);
        let data: Vec<u8> =
            (0..33 * 14 * 3).map(|_| rng.next_u32() as u8).collect();
        let img = ColorImage::from_vec(33, 14, data).unwrap();
        let back = decode_rgb(&encode_rgb(&img).unwrap()).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn rgb_png_decodes_to_luma_gray() {
        let img = ColorImage::from_vec(1, 1, vec![0, 255, 0]).unwrap();
        let g = decode(&encode_rgb(&img).unwrap()).unwrap();
        assert_eq!(g.data[0], 150); // 0.587 * 255
    }

    #[test]
    fn gray_png_decodes_to_replicated_rgb() {
        let img = GrayImage::from_vec(2, 1, vec![9, 250]).unwrap();
        let c = decode_rgb(&encode(&img).unwrap()).unwrap();
        assert_eq!(c.data, vec![9, 9, 9, 250, 250, 250]);
    }

    #[test]
    fn natural_rgb_filters_and_compresses() {
        let img = synthetic::lena_like_rgb(64, 48, 3);
        let enc = encode_rgb(&img).unwrap();
        let back = decode_rgb(&enc).unwrap();
        assert_eq!(img, back);
        assert!(enc.len() < img.bytes());
    }
}
