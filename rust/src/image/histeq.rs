//! Serial histogram equalization — the CPU lane of the paper's Tables 1-2
//! caption workload ("time comparisons of grayscale histogram/equalization
//! ... CPU and GPU"). The GPU lane is the `histeq_*` PJRT artifact.
//!
//! The arithmetic mirrors `python/compile/kernels/histeq.py` exactly
//! (same LUT normalization) so both lanes produce identical pixels.

use super::GrayImage;

/// 256-bin histogram.
pub fn histogram(img: &GrayImage) -> [u64; 256] {
    let mut hist = [0u64; 256];
    for &v in &img.data {
        hist[v as usize] += 1;
    }
    hist
}

/// Equalization LUT from a histogram (classic scaled-CDF formulation with
/// the first occupied bin mapping to 0).
pub fn equalization_lut(hist: &[u64; 256], npix: u64) -> [u8; 256] {
    let mut cdf = [0u64; 256];
    let mut acc = 0u64;
    for (i, &h) in hist.iter().enumerate() {
        acc += h;
        cdf[i] = acc;
    }
    let cdf_min = hist
        .iter()
        .position(|&h| h > 0)
        .map(|i| cdf[i])
        .unwrap_or(0);
    let denom = (npix.saturating_sub(cdf_min)).max(1) as f64;
    let mut lut = [0u8; 256];
    for i in 0..256 {
        let v = ((cdf[i].saturating_sub(cdf_min)) as f64 / denom * 255.0)
            .round()
            .clamp(0.0, 255.0);
        lut[i] = v as u8;
    }
    lut
}

/// Full serial histogram equalization.
pub fn histeq(img: &GrayImage) -> GrayImage {
    let hist = histogram(img);
    let lut = equalization_lut(&hist, img.pixels() as u64);
    GrayImage {
        width: img.width,
        height: img.height,
        data: img.data.iter().map(|&v| lut[v as usize]).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    #[test]
    fn histogram_totals() {
        let img = synthetic::lena_like(40, 40, 1);
        let h = histogram(&img);
        assert_eq!(h.iter().sum::<u64>(), 1600);
    }

    #[test]
    fn constant_image_maps_to_zero() {
        // single occupied bin: cdf - cdf_min == 0 everywhere occupied
        let img = GrayImage::from_vec(4, 4, vec![99; 16]).unwrap();
        let out = histeq(&img);
        assert!(out.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn stretches_low_contrast() {
        let mut img = GrayImage::new(64, 64);
        let mut rng = crate::util::prng::Rng::new(4);
        for v in &mut img.data {
            *v = rng.range_i64(100, 140) as u8;
        }
        let out = histeq(&img);
        let span_in = *img.data.iter().max().unwrap() as i32
            - *img.data.iter().min().unwrap() as i32;
        let span_out = *out.data.iter().max().unwrap() as i32
            - *out.data.iter().min().unwrap() as i32;
        assert!(span_out > span_in * 3, "{span_in} -> {span_out}");
    }

    #[test]
    fn mapping_is_monotone() {
        let img = synthetic::cablecar_like(64, 64, 2);
        let hist = histogram(&img);
        let lut = equalization_lut(&hist, img.pixels() as u64);
        for i in 1..256 {
            assert!(lut[i] >= lut[i - 1]);
        }
    }

    #[test]
    fn full_ramp_near_identity() {
        let mut img = GrayImage::new(256, 8);
        for y in 0..8 {
            for x in 0..256 {
                img.set(x, y, x as u8);
            }
        }
        let out = histeq(&img);
        for x in 0..256 {
            let d = (out.get(x, 0) as i32 - x as i32).abs();
            assert!(d <= 2, "x {x} diff {d}");
        }
    }
}
