//! Interleaved RGB8 color image type and I/O.
//!
//! The color workload decomposes into YCbCr planes (see [`super::ycbcr`])
//! so every transform/quantize/entropy stage still runs on the grayscale
//! [`GrayImage`] plane type; `ColorImage` only exists at the boundary —
//! file I/O, conversion, and final reassembly.

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::{bmp, pgm, png, GrayImage};

/// 8-bit RGB image, row-major, channels interleaved (R, G, B).
#[derive(Clone, PartialEq, Eq)]
pub struct ColorImage {
    pub width: usize,
    pub height: usize,
    /// `width * height * 3` bytes, `[r, g, b, r, g, b, ...]` per row.
    pub data: Vec<u8>,
}

impl std::fmt::Debug for ColorImage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ColorImage({}x{})", self.width, self.height)
    }
}

impl ColorImage {
    pub fn new(width: usize, height: usize) -> Self {
        ColorImage {
            width,
            height,
            data: vec![0; width * height * 3],
        }
    }

    pub fn from_vec(width: usize, height: usize, data: Vec<u8>) -> Result<Self> {
        if data.len() != width * height * 3 {
            bail!(
                "RGB byte count {} != {}x{}x3",
                data.len(),
                width,
                height
            );
        }
        Ok(ColorImage {
            width,
            height,
            data,
        })
    }

    /// Replicate a grayscale image into all three channels (R = G = B).
    pub fn from_gray(img: &GrayImage) -> Self {
        let mut data = Vec::with_capacity(img.pixels() * 3);
        for &v in &img.data {
            data.extend_from_slice(&[v, v, v]);
        }
        ColorImage {
            width: img.width,
            height: img.height,
            data,
        }
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> [u8; 3] {
        let i = (y * self.width + x) * 3;
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, rgb: [u8; 3]) {
        let i = (y * self.width + x) * 3;
        self.data[i..i + 3].copy_from_slice(&rgb);
    }

    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// Raw byte size of the uncompressed representation.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Extract one channel (0 = R, 1 = G, 2 = B) as a grayscale plane.
    pub fn channel(&self, c: usize) -> GrayImage {
        assert!(c < 3, "channel index {c}");
        GrayImage {
            width: self.width,
            height: self.height,
            data: self.data.iter().skip(c).step_by(3).copied().collect(),
        }
    }

    /// Collapse to grayscale via BT.601 luma (matches the gray decoders).
    pub fn to_gray(&self) -> GrayImage {
        GrayImage {
            width: self.width,
            height: self.height,
            data: self
                .data
                .chunks_exact(3)
                .map(|p| {
                    super::luma_f32(
                        p[0] as f32,
                        p[1] as f32,
                        p[2] as f32,
                    )
                })
                .collect(),
        }
    }

    /// Load by extension: .ppm, .bmp, .png (kept in color).
    pub fn load(path: impl AsRef<Path>) -> Result<ColorImage> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        match super::ext(path).as_deref() {
            Some("ppm") => pgm::decode_rgb(&bytes),
            Some("bmp") => bmp::decode_rgb(&bytes),
            Some("png") => png::decode_rgb(&bytes),
            _ => bail!(
                "unsupported color image extension: {}",
                path.display()
            ),
        }
    }

    /// Save by extension: .ppm, .bmp, .png.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let bytes = match super::ext(path).as_deref() {
            Some("ppm") => pgm::encode_rgb(self),
            Some("bmp") => bmp::encode_rgb(self),
            Some("png") => png::encode_rgb(self)?,
            _ => bail!(
                "unsupported color image extension: {}",
                path.display()
            ),
        };
        std::fs::write(path, bytes)
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates() {
        assert!(ColorImage::from_vec(2, 2, vec![0; 11]).is_err());
        assert!(ColorImage::from_vec(2, 2, vec![0; 12]).is_ok());
    }

    #[test]
    fn get_set_roundtrip() {
        let mut img = ColorImage::new(3, 2);
        img.set(2, 1, [10, 20, 30]);
        assert_eq!(img.get(2, 1), [10, 20, 30]);
        assert_eq!(img.get(0, 0), [0, 0, 0]);
    }

    #[test]
    fn channels_extract() {
        let img =
            ColorImage::from_vec(2, 1, vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(img.channel(0).data, vec![1, 4]);
        assert_eq!(img.channel(1).data, vec![2, 5]);
        assert_eq!(img.channel(2).data, vec![3, 6]);
    }

    #[test]
    fn from_gray_replicates() {
        let g = GrayImage::from_vec(2, 1, vec![7, 9]).unwrap();
        let c = ColorImage::from_gray(&g);
        assert_eq!(c.data, vec![7, 7, 7, 9, 9, 9]);
        assert_eq!(c.to_gray(), g);
    }

    #[test]
    fn to_gray_is_luma() {
        let img =
            ColorImage::from_vec(1, 1, vec![255, 0, 0]).unwrap();
        assert_eq!(img.to_gray().data[0], 76); // 0.299 * 255
    }
}
