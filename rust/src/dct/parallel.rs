//! Block-parallel CPU compression pipeline — the third lane.
//!
//! The paper compares *serial* CPU code against CUDA; a fair modern-CPU
//! baseline also needs the multi-core number (the parallel-vs-sequential
//! methodology of Haque et al., arXiv:1404.0774). This pipeline partitions
//! the padded block grid into row-band tiles (one band = one row of 8x8
//! blocks) and fans the bands out over scoped worker threads
//! ([`crate::util::threadpool::parallel_map`], which may borrow the image).
//!
//! Within each band the blocks run on the same 8-wide
//! [`BatchEngine`](super::batch::BatchEngine) as the serial lane, each
//! band worker checking a [`BlockScratch`](super::batch::BlockScratch)
//! buffer out of the shared per-pipeline arena.
//!
//! Bit-exactness: every block runs the exact same arithmetic as the
//! serial [`CpuPipeline`](super::pipeline::CpuPipeline) — the batched
//! engine is lane-for-lane the
//! scalar op sequence — and blocks are independent, so `qcoef` and the
//! reconstruction are bit-identical to the serial lane for every
//! [`Variant`] and quality (asserted by `tests/parallel_parity.rs` and
//! `tests/batch_parity.rs`).

use crate::codec::encoder::ScanCoefs;
use crate::image::GrayImage;

use super::batch::{BatchEngine, EngineConfig};
use super::blocks::{self, grid_dims, pad_to_blocks};
use super::pipeline::{CpuCompressOutput, FusedCompressOutput};
use super::quant::effective_qtable;
use super::Variant;
use crate::util::threadpool::{parallel_map, ThreadPool};

/// Block-parallel compression pipeline: serial arithmetic, parallel grid.
pub struct ParallelCpuPipeline {
    engine: BatchEngine,
    pub variant: Variant,
    pub quality: u8,
    workers: usize,
}

impl ParallelCpuPipeline {
    /// Pipeline with the machine-default worker count.
    pub fn new(variant: Variant, quality: u8) -> Self {
        Self::with_workers(variant, quality, 0)
    }

    /// Pipeline with an explicit worker count (`0` = machine default).
    pub fn with_workers(variant: Variant, quality: u8, workers: usize) -> Self {
        Self::with_qtable(
            variant,
            quality,
            workers,
            effective_qtable(quality),
        )
    }

    /// Pipeline with an explicit [`EngineConfig`] (lane width + fxp
    /// precision) and the machine-default worker count.
    pub fn with_config(
        variant: Variant,
        quality: u8,
        cfg: EngineConfig,
    ) -> Self {
        Self::with_qtable_config(
            variant,
            quality,
            0,
            effective_qtable(quality),
            cfg,
        )
    }

    /// Pipeline with an explicit worker count and effective quantization
    /// table (the color path passes the chroma table for Cb/Cr planes).
    pub fn with_qtable(
        variant: Variant,
        quality: u8,
        workers: usize,
        qtable: [f32; 64],
    ) -> Self {
        Self::with_qtable_config(
            variant,
            quality,
            workers,
            qtable,
            EngineConfig::default(),
        )
    }

    /// Explicit worker count, table *and* engine config — the fully
    /// general ctor all the others delegate to.
    pub fn with_qtable_config(
        variant: Variant,
        quality: u8,
        workers: usize,
        qtable: [f32; 64],
        cfg: EngineConfig,
    ) -> Self {
        let workers = if workers == 0 {
            ThreadPool::default_size()
        } else {
            workers
        };
        ParallelCpuPipeline {
            engine: BatchEngine::with_config(variant, qtable, cfg),
            variant,
            quality,
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn transform_name(&self) -> &'static str {
        self.engine.transform_name()
    }

    /// One row-band of blocks: forward transform + quantize (+ the
    /// outputs requested) into band-local buffers (planar row, fused
    /// zigzag row, decoded pixels). Runs on a worker thread with a
    /// scratch buffer from the pipeline's arena. Band buffers
    /// concatenate in block-row order into the whole-image layouts.
    fn process_band(
        &self,
        padded: &GrayImage,
        by: usize,
        planar: bool,
        scan: bool,
        decode: bool,
    ) -> (Option<Vec<f32>>, Option<Vec<i16>>, Option<GrayImage>) {
        let w = padded.width;
        let mut qrow = planar.then(|| vec![0.0f32; w * blocks::BLOCK]);
        let mut srow = scan.then(|| vec![0i16; w * blocks::BLOCK]);
        let mut band = decode.then(|| GrayImage::new(w, blocks::BLOCK));
        self.engine.with_scratch(|s| {
            let recon = band.as_mut().map(|img| (img, 0));
            self.engine.forward_quant_row(
                s,
                padded,
                by,
                qrow.as_deref_mut(),
                0,
                srow.as_deref_mut(),
                recon,
            );
        });
        (qrow, srow, band)
    }

    /// Full pipeline over an image; bit-identical to
    /// [`CpuPipeline::compress`](super::pipeline::CpuPipeline::compress).
    pub fn compress(&self, img: &GrayImage) -> CpuCompressOutput {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let bands = parallel_map(gh, self.workers, |by| {
            self.process_band(&padded, by, true, true, true)
        });
        let mut qcoef = Vec::with_capacity(padded.pixels());
        let mut scanned = Vec::with_capacity(padded.pixels());
        let mut pixels = Vec::with_capacity(padded.pixels());
        for (qrow, srow, band) in bands {
            qcoef.extend_from_slice(&qrow.expect("planar band"));
            scanned.extend_from_slice(&srow.expect("scanned band"));
            pixels.extend_from_slice(&band.expect("decoded band").data);
        }
        let recon = GrayImage {
            width: padded.width,
            height: padded.height,
            data: pixels,
        };
        let recon = if (padded.width, padded.height)
            != (img.width, img.height)
        {
            recon.crop(img.width, img.height).expect("crop to original")
        } else {
            recon
        };
        CpuCompressOutput {
            recon,
            qcoef,
            scanned: ScanCoefs {
                width: img.width,
                height: img.height,
                padded_width: padded.width,
                padded_height: padded.height,
                data: scanned,
            },
            padded_width: padded.width,
            padded_height: padded.height,
        }
    }

    /// Full pipeline without the planar f32 buffer; bit-identical
    /// recon/scanned to
    /// [`CpuPipeline::compress_fused`](super::pipeline::CpuPipeline::compress_fused).
    pub fn compress_fused(&self, img: &GrayImage) -> FusedCompressOutput {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let bands = parallel_map(gh, self.workers, |by| {
            let (_, srow, band) =
                self.process_band(&padded, by, false, true, true);
            (srow, band)
        });
        let mut scanned = Vec::with_capacity(padded.pixels());
        let mut pixels = Vec::with_capacity(padded.pixels());
        for (srow, band) in bands {
            scanned.extend_from_slice(&srow.expect("scanned band"));
            pixels.extend_from_slice(&band.expect("decoded band").data);
        }
        let recon = GrayImage {
            width: padded.width,
            height: padded.height,
            data: pixels,
        };
        let recon = if (padded.width, padded.height)
            != (img.width, img.height)
        {
            recon.crop(img.width, img.height).expect("crop to original")
        } else {
            recon
        };
        FusedCompressOutput {
            recon,
            scanned: ScanCoefs {
                width: img.width,
                height: img.height,
                padded_width: padded.width,
                padded_height: padded.height,
                data: scanned,
            },
        }
    }

    /// Forward transform + quantization straight to entropy-coding order,
    /// band-parallel; no planar buffer and no reconstruction.
    pub fn analyze_scanned(&self, img: &GrayImage) -> ScanCoefs {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let bands = parallel_map(gh, self.workers, |by| {
            self.process_band(&padded, by, false, true, false).1
        });
        let mut scanned = Vec::with_capacity(padded.pixels());
        for srow in bands {
            scanned.extend_from_slice(&srow.expect("scanned band"));
        }
        ScanCoefs {
            width: img.width,
            height: img.height,
            padded_width: padded.width,
            padded_height: padded.height,
            data: scanned,
        }
    }

    /// Forward transform + quantization only; bit-identical to
    /// [`CpuPipeline::analyze`](super::pipeline::CpuPipeline::analyze).
    pub fn analyze(&self, img: &GrayImage) -> (Vec<f32>, usize, usize) {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let bands = parallel_map(gh, self.workers, |by| {
            self.process_band(&padded, by, true, false, false)
                .0
                .expect("planar band")
        });
        let mut qcoef = Vec::with_capacity(padded.pixels());
        for qrow in bands {
            qcoef.extend_from_slice(&qrow);
        }
        (qcoef, padded.width, padded.height)
    }

    /// Decode planar quantized coefficients back to an image, band-parallel.
    pub fn decode_coefficients(
        &self,
        qcoef: &[f32],
        padded_width: usize,
        padded_height: usize,
        out_width: usize,
        out_height: usize,
    ) -> GrayImage {
        let (_, gh) = grid_dims(padded_width, padded_height);
        let bands = parallel_map(gh, self.workers, |by| {
            let mut band = GrayImage::new(padded_width, blocks::BLOCK);
            self.engine.with_scratch(|s| {
                self.engine.decode_row(
                    s,
                    qcoef,
                    padded_width,
                    by,
                    &mut band,
                    0,
                );
            });
            band.data
        });
        let mut pixels = Vec::with_capacity(padded_width * padded_height);
        for band in bands {
            pixels.extend_from_slice(&band);
        }
        let recon = GrayImage {
            width: padded_width,
            height: padded_height,
            data: pixels,
        };
        if (padded_width, padded_height) != (out_width, out_height) {
            recon.crop(out_width, out_height).expect("crop")
        } else {
            recon
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::pipeline::CpuPipeline;
    use crate::image::synthetic;
    use crate::metrics::psnr;

    #[test]
    fn matches_serial_on_aligned_image() {
        let img = synthetic::lena_like(64, 64, 1);
        let serial = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        let par = ParallelCpuPipeline::with_workers(Variant::Dct, 50, 4)
            .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef);
        assert_eq!(par.scanned, serial.scanned);
        assert_eq!(par.recon, serial.recon);
        assert_eq!(
            (par.padded_width, par.padded_height),
            (serial.padded_width, serial.padded_height)
        );
    }

    #[test]
    fn matches_serial_on_unaligned_image() {
        let img = synthetic::cablecar_like(30, 21, 4);
        let serial = CpuPipeline::new(Variant::Cordic, 50).compress(&img);
        let par = ParallelCpuPipeline::with_workers(Variant::Cordic, 50, 3)
            .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef);
        assert_eq!(par.scanned, serial.scanned);
        assert_eq!(par.recon, serial.recon);
        assert_eq!((par.recon.width, par.recon.height), (30, 21));
    }

    #[test]
    fn analyze_matches_compress() {
        let img = synthetic::lena_like(40, 32, 5);
        let pipe = ParallelCpuPipeline::with_workers(Variant::Dct, 50, 2);
        let full = pipe.compress(&img);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        assert_eq!(qcoef, full.qcoef);
        let recon = pipe.decode_coefficients(&qcoef, pw, ph, 40, 32);
        assert_eq!(recon, full.recon);
    }

    #[test]
    fn single_worker_is_fine() {
        let img = synthetic::lena_like(24, 24, 2);
        let serial = CpuPipeline::new(Variant::Loeffler, 75).compress(&img);
        let par =
            ParallelCpuPipeline::with_workers(Variant::Loeffler, 75, 1)
                .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef);
        assert!(psnr(&img, &par.recon) > 28.0);
    }

    #[test]
    fn fused_matches_serial_pipeline() {
        let img = synthetic::lena_like(30, 21, 4);
        let serial = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        let pipe = ParallelCpuPipeline::with_workers(Variant::Dct, 50, 3);
        let fused = pipe.compress_fused(&img);
        assert_eq!(fused.recon, serial.recon);
        assert_eq!(fused.scanned, serial.scanned);
        assert_eq!(pipe.analyze_scanned(&img), serial.scanned);
    }

    #[test]
    fn default_workers_at_least_one() {
        let p = ParallelCpuPipeline::new(Variant::Dct, 50);
        assert!(p.workers() >= 1);
        assert_eq!(p.transform_name(), "matrix");
    }
}
