//! Block-parallel CPU compression pipeline — the third lane.
//!
//! The paper compares *serial* CPU code against CUDA; a fair modern-CPU
//! baseline also needs the multi-core number (the parallel-vs-sequential
//! methodology of Haque et al., arXiv:1404.0774). This pipeline partitions
//! the padded block grid into row-band tiles (one band = one row of 8x8
//! blocks) and fans the bands out over scoped worker threads
//! ([`crate::util::threadpool::parallel_map`], which may borrow the image).
//!
//! Bit-exactness: every block runs the exact same code path as the serial
//! [`CpuPipeline`] — same `extract_block` / `forward` / `quantize` /
//! `dequantize` / `inverse` / `store_block` calls on the same `f32`
//! values — and blocks are independent, so `qcoef` and the reconstruction
//! are bit-identical to the serial lane for every [`Variant`] and quality
//! (asserted by `tests/parallel_parity.rs`).

use crate::image::GrayImage;

use super::blocks::{
    self, extract_block, grid_dims, load_coef_planar, pad_to_blocks,
    store_block, store_coef_planar,
};
use super::matrix::MatrixDct;
use super::pipeline::CpuCompressOutput;
use super::quant::{dequantize_block, effective_qtable, quantize_block};
use super::{Transform8x8, Variant};
use crate::util::threadpool::{parallel_map, ThreadPool};

/// Block-parallel compression pipeline: serial arithmetic, parallel grid.
pub struct ParallelCpuPipeline {
    transform: Box<dyn Transform8x8>,
    decoder: MatrixDct,
    qtable: [f32; 64],
    pub variant: Variant,
    pub quality: u8,
    workers: usize,
}

impl ParallelCpuPipeline {
    /// Pipeline with the machine-default worker count.
    pub fn new(variant: Variant, quality: u8) -> Self {
        Self::with_workers(variant, quality, 0)
    }

    /// Pipeline with an explicit worker count (`0` = machine default).
    pub fn with_workers(variant: Variant, quality: u8, workers: usize) -> Self {
        Self::with_qtable(
            variant,
            quality,
            workers,
            effective_qtable(quality),
        )
    }

    /// Pipeline with an explicit worker count and effective quantization
    /// table (the color path passes the chroma table for Cb/Cr planes).
    pub fn with_qtable(
        variant: Variant,
        quality: u8,
        workers: usize,
        qtable: [f32; 64],
    ) -> Self {
        let workers = if workers == 0 {
            ThreadPool::default_size()
        } else {
            workers
        };
        ParallelCpuPipeline {
            transform: variant.transform(),
            decoder: MatrixDct::new(),
            qtable,
            variant,
            quality,
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn transform_name(&self) -> &'static str {
        self.transform.name()
    }

    /// One row-band of blocks: forward transform + quantize (+ optionally
    /// decode) into band-local buffers. Runs on a worker thread.
    fn process_band(
        &self,
        padded: &GrayImage,
        by: usize,
        gw: usize,
        decode: bool,
    ) -> (Vec<f32>, Option<GrayImage>) {
        let w = padded.width;
        let mut qrow = vec![0.0f32; w * blocks::BLOCK];
        let mut band = decode.then(|| GrayImage::new(w, blocks::BLOCK));
        let mut block = [0.0f32; 64];
        let mut qc = [0i16; 64];
        for bx in 0..gw {
            extract_block(padded, bx, by, &mut block);
            self.transform.forward(&mut block);
            quantize_block(&block, &self.qtable, &mut qc);
            // band-local planar layout: same helper, block-row 0
            store_coef_planar(&mut qrow, w, bx, 0, &qc);
            if let Some(band) = band.as_mut() {
                dequantize_block(&qc, &self.qtable, &mut block);
                self.decoder.inverse(&mut block);
                store_block(band, bx, 0, &block);
            }
        }
        (qrow, band)
    }

    /// Full pipeline over an image; bit-identical to
    /// [`CpuPipeline::compress`](super::pipeline::CpuPipeline::compress).
    pub fn compress(&self, img: &GrayImage) -> CpuCompressOutput {
        let padded = pad_to_blocks(img);
        let (gw, gh) = grid_dims(padded.width, padded.height);
        let bands = parallel_map(gh, self.workers, |by| {
            self.process_band(&padded, by, gw, true)
        });
        let mut qcoef = Vec::with_capacity(padded.pixels());
        let mut pixels = Vec::with_capacity(padded.pixels());
        for (qrow, band) in bands {
            qcoef.extend_from_slice(&qrow);
            pixels.extend_from_slice(&band.expect("decoded band").data);
        }
        let recon = GrayImage {
            width: padded.width,
            height: padded.height,
            data: pixels,
        };
        let recon = if (padded.width, padded.height)
            != (img.width, img.height)
        {
            recon.crop(img.width, img.height).expect("crop to original")
        } else {
            recon
        };
        CpuCompressOutput {
            recon,
            qcoef,
            padded_width: padded.width,
            padded_height: padded.height,
        }
    }

    /// Forward transform + quantization only; bit-identical to
    /// [`CpuPipeline::analyze`](super::pipeline::CpuPipeline::analyze).
    pub fn analyze(&self, img: &GrayImage) -> (Vec<f32>, usize, usize) {
        let padded = pad_to_blocks(img);
        let (gw, gh) = grid_dims(padded.width, padded.height);
        let bands = parallel_map(gh, self.workers, |by| {
            self.process_band(&padded, by, gw, false).0
        });
        let mut qcoef = Vec::with_capacity(padded.pixels());
        for qrow in bands {
            qcoef.extend_from_slice(&qrow);
        }
        (qcoef, padded.width, padded.height)
    }

    /// Decode planar quantized coefficients back to an image, band-parallel.
    pub fn decode_coefficients(
        &self,
        qcoef: &[f32],
        padded_width: usize,
        padded_height: usize,
        out_width: usize,
        out_height: usize,
    ) -> GrayImage {
        let (gw, gh) = grid_dims(padded_width, padded_height);
        let bands = parallel_map(gh, self.workers, |by| {
            let mut band = GrayImage::new(padded_width, blocks::BLOCK);
            let mut qc = [0i16; 64];
            let mut block = [0.0f32; 64];
            for bx in 0..gw {
                load_coef_planar(qcoef, padded_width, bx, by, &mut qc);
                dequantize_block(&qc, &self.qtable, &mut block);
                self.decoder.inverse(&mut block);
                store_block(&mut band, bx, 0, &block);
            }
            band.data
        });
        let mut pixels = Vec::with_capacity(padded_width * padded_height);
        for band in bands {
            pixels.extend_from_slice(&band);
        }
        let recon = GrayImage {
            width: padded_width,
            height: padded_height,
            data: pixels,
        };
        if (padded_width, padded_height) != (out_width, out_height) {
            recon.crop(out_width, out_height).expect("crop")
        } else {
            recon
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::pipeline::CpuPipeline;
    use crate::image::synthetic;
    use crate::metrics::psnr;

    #[test]
    fn matches_serial_on_aligned_image() {
        let img = synthetic::lena_like(64, 64, 1);
        let serial = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        let par = ParallelCpuPipeline::with_workers(Variant::Dct, 50, 4)
            .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef);
        assert_eq!(par.recon, serial.recon);
        assert_eq!(
            (par.padded_width, par.padded_height),
            (serial.padded_width, serial.padded_height)
        );
    }

    #[test]
    fn matches_serial_on_unaligned_image() {
        let img = synthetic::cablecar_like(30, 21, 4);
        let serial = CpuPipeline::new(Variant::Cordic, 50).compress(&img);
        let par = ParallelCpuPipeline::with_workers(Variant::Cordic, 50, 3)
            .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef);
        assert_eq!(par.recon, serial.recon);
        assert_eq!((par.recon.width, par.recon.height), (30, 21));
    }

    #[test]
    fn analyze_matches_compress() {
        let img = synthetic::lena_like(40, 32, 5);
        let pipe = ParallelCpuPipeline::with_workers(Variant::Dct, 50, 2);
        let full = pipe.compress(&img);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        assert_eq!(qcoef, full.qcoef);
        let recon = pipe.decode_coefficients(&qcoef, pw, ph, 40, 32);
        assert_eq!(recon, full.recon);
    }

    #[test]
    fn single_worker_is_fine() {
        let img = synthetic::lena_like(24, 24, 2);
        let serial = CpuPipeline::new(Variant::Loeffler, 75).compress(&img);
        let par =
            ParallelCpuPipeline::with_workers(Variant::Loeffler, 75, 1)
                .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef);
        assert!(psnr(&img, &par.recon) > 28.0);
    }

    #[test]
    fn default_workers_at_least_one() {
        let p = ParallelCpuPipeline::new(Variant::Dct, 50);
        assert!(p.workers() >= 1);
        assert_eq!(p.transform_name(), "matrix");
    }
}
