//! Separable matrix DCT: two 8x8 matrix products per block
//! (rows then columns). The fastest *exact* scalar implementation here and
//! the arithmetic twin of the Pallas `transform_strip_matrix` kernel.

use super::{dct_matrix, Transform8x8};

pub struct MatrixDct {
    d: [[f32; 8]; 8],
    /// Transpose of `d`, so the row pass reads contiguous rows.
    dt: [[f32; 8]; 8],
}

impl MatrixDct {
    pub fn new() -> Self {
        let d = dct_matrix();
        let mut dt = [[0.0f32; 8]; 8];
        for k in 0..8 {
            for n in 0..8 {
                dt[n][k] = d[k][n];
            }
        }
        MatrixDct { d, dt }
    }

    /// The orthonormal DCT matrix, for the lane-wide batch kernels.
    pub(crate) fn coeffs(&self) -> &[[f32; 8]; 8] {
        &self.d
    }
}

impl Default for MatrixDct {
    fn default() -> Self {
        Self::new()
    }
}

impl Transform8x8 for MatrixDct {
    fn name(&self) -> &'static str {
        "matrix"
    }

    /// B <- D B D^T, computed as two separable passes.
    ///
    /// Row-major unrolled form: each pass accumulates whole 8-wide rows
    /// (`acc[j] += d * row[j]`) so the autovectorizer maps the inner loop
    /// onto vector adds/muls. The per-element accumulation order is
    /// unchanged from the textbook triple loop (ascending `n`/`j`), so
    /// the output stays bit-identical.
    fn forward(&self, block: &mut [f32; 64]) {
        let d = &self.d;
        let dt = &self.dt;
        let mut tmp = [0.0f32; 64];
        // columns: tmp = D * B — row k of tmp accumulates rows of B
        for k in 0..8 {
            let mut acc = [0.0f32; 8];
            for n in 0..8 {
                let dkn = d[k][n];
                let row = &block[n * 8..n * 8 + 8];
                for j in 0..8 {
                    acc[j] += dkn * row[j];
                }
            }
            tmp[k * 8..k * 8 + 8].copy_from_slice(&acc);
        }
        // rows: out = tmp * D^T — out row k accumulates rows of D^T
        for k in 0..8 {
            let mut acc = [0.0f32; 8];
            for j in 0..8 {
                let tkj = tmp[k * 8 + j];
                let row = &dt[j];
                for l in 0..8 {
                    acc[l] += tkj * row[l];
                }
            }
            block[k * 8..k * 8 + 8].copy_from_slice(&acc);
        }
    }

    /// B <- D^T B D (same row-major unrolled form as `forward`).
    fn inverse(&self, block: &mut [f32; 64]) {
        let d = &self.d;
        let mut tmp = [0.0f32; 64];
        for i in 0..8 {
            let mut acc = [0.0f32; 8];
            for k in 0..8 {
                let dki = d[k][i];
                let row = &block[k * 8..k * 8 + 8];
                for j in 0..8 {
                    acc[j] += dki * row[j];
                }
            }
            tmp[i * 8..i * 8 + 8].copy_from_slice(&acc);
        }
        for i in 0..8 {
            let mut acc = [0.0f32; 8];
            for l in 0..8 {
                let til = tmp[i * 8 + l];
                let row = &d[l];
                for j in 0..8 {
                    acc[j] += til * row[j];
                }
            }
            block[i * 8..i * 8 + 8].copy_from_slice(&acc);
        }
    }

    fn ops_per_block(&self) -> (usize, usize) {
        // two 8x8x8 matmuls
        (2 * 8 * 8 * 8, 2 * 8 * 8 * 7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::naive::NaiveDct;
    use crate::util::prng::Rng;

    fn rand_block(seed: u64) -> [f32; 64] {
        let mut rng = Rng::new(seed);
        let mut b = [0.0f32; 64];
        for v in &mut b {
            *v = rng.range_f64(-128.0, 128.0) as f32;
        }
        b
    }

    #[test]
    fn matches_naive() {
        let m = MatrixDct::new();
        let n = NaiveDct::new();
        for seed in 0..6 {
            let mut a = rand_block(seed);
            let mut b = a;
            m.forward(&mut a);
            n.forward(&mut b);
            for i in 0..64 {
                assert!((a[i] - b[i]).abs() < 1e-3, "{i}: {} {}", a[i], b[i]);
            }
        }
    }

    #[test]
    fn inverse_matches_naive() {
        let m = MatrixDct::new();
        let n = NaiveDct::new();
        let mut a = rand_block(7);
        let mut b = a;
        m.inverse(&mut a);
        n.inverse(&mut b);
        for i in 0..64 {
            assert!((a[i] - b[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn roundtrip() {
        let m = MatrixDct::new();
        let orig = rand_block(11);
        let mut b = orig;
        m.forward(&mut b);
        m.inverse(&mut b);
        for i in 0..64 {
            assert!((b[i] - orig[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn impulse_response_is_basis_row() {
        let m = MatrixDct::new();
        let d = dct_matrix();
        let mut b = [0.0f32; 64];
        b[0] = 1.0; // impulse at (0,0)
        m.forward(&mut b);
        for u in 0..8 {
            for v in 0..8 {
                let want = d[u][0] * d[v][0];
                assert!((b[u * 8 + v] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn cheaper_than_naive() {
        assert!(MatrixDct::new().ops_per_block().0
            < NaiveDct::new().ops_per_block().0);
    }
}
