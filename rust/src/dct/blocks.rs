//! Block management: 8-alignment padding, (de)blockification, level shift.
//!
//! The paper's pipeline operates on 8x8 blocks of a level-shifted image;
//! this module owns the layout plumbing shared by the CPU pipeline, the
//! entropy codec and the coordinator (which submits padded images to the
//! PJRT artifacts and crops the results).

use crate::image::GrayImage;

pub const BLOCK: usize = 8;
pub const LEVEL_SHIFT: f32 = 128.0;

/// Round up to the next multiple of 8.
#[inline]
pub fn align8(n: usize) -> usize {
    n.div_ceil(BLOCK) * BLOCK
}

/// Pad an image to 8-aligned dimensions with edge replication.
/// Returns the padded image (may be a clone if already aligned).
pub fn pad_to_blocks(img: &GrayImage) -> GrayImage {
    let (w, h) = (align8(img.width), align8(img.height));
    if (w, h) == (img.width, img.height) {
        img.clone()
    } else {
        img.pad_edge(w, h).expect("pad_edge grows")
    }
}

/// Block grid dimensions of an aligned image.
#[inline]
pub fn grid_dims(width: usize, height: usize) -> (usize, usize) {
    debug_assert!(width % BLOCK == 0 && height % BLOCK == 0);
    (width / BLOCK, height / BLOCK)
}

/// Extract block (bx, by) of an aligned image into `out`, applying the
/// -128 level shift.
#[inline]
pub fn extract_block(
    img: &GrayImage,
    bx: usize,
    by: usize,
    out: &mut [f32; 64],
) {
    let w = img.width;
    for r in 0..BLOCK {
        let src = (by * BLOCK + r) * w + bx * BLOCK;
        for c in 0..BLOCK {
            out[r * BLOCK + c] = img.data[src + c] as f32 - LEVEL_SHIFT;
        }
    }
}

/// Write a reconstructed block back (un-shift + clamp to u8).
#[inline]
pub fn store_block(img: &mut GrayImage, bx: usize, by: usize, block: &[f32; 64]) {
    let w = img.width;
    for r in 0..BLOCK {
        let dst = (by * BLOCK + r) * w + bx * BLOCK;
        for c in 0..BLOCK {
            img.data[dst + c] = (block[r * BLOCK + c] + LEVEL_SHIFT)
                .clamp(0.0, 255.0)
                .round() as u8;
        }
    }
}

/// Copy a quantized-coefficient block into the planar (image-layout)
/// coefficient buffer used by the PJRT interchange.
#[inline]
pub fn store_coef_planar(
    buf: &mut [f32],
    width: usize,
    bx: usize,
    by: usize,
    qc: &[i16; 64],
) {
    for r in 0..BLOCK {
        let dst = (by * BLOCK + r) * width + bx * BLOCK;
        for c in 0..BLOCK {
            buf[dst + c] = qc[r * BLOCK + c] as f32;
        }
    }
}

/// Gather a block from a planar f32 coefficient buffer (the PJRT output
/// layout) into block order as i16.
#[inline]
pub fn load_coef_planar(
    buf: &[f32],
    width: usize,
    bx: usize,
    by: usize,
    out: &mut [i16; 64],
) {
    for r in 0..BLOCK {
        let src = (by * BLOCK + r) * width + bx * BLOCK;
        for c in 0..BLOCK {
            out[r * BLOCK + c] = buf[src + c].round_ties_even() as i16;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    #[test]
    fn align8_values() {
        assert_eq!(align8(0), 0);
        assert_eq!(align8(1), 8);
        assert_eq!(align8(8), 8);
        assert_eq!(align8(814), 816);
        assert_eq!(align8(200), 200);
    }

    #[test]
    fn pad_already_aligned_is_same() {
        let img = synthetic::lena_like(16, 24, 1);
        let p = pad_to_blocks(&img);
        assert_eq!(p, img);
    }

    #[test]
    fn pad_unaligned_grows_and_replicates() {
        let img = synthetic::lena_like(13, 9, 2);
        let p = pad_to_blocks(&img);
        assert_eq!((p.width, p.height), (16, 16));
        assert_eq!(p.get(15, 5), img.get(12, 5));
        assert_eq!(p.get(4, 15), img.get(4, 8));
    }

    #[test]
    fn extract_store_roundtrip() {
        let img = synthetic::lena_like(24, 16, 3);
        let mut out = GrayImage::new(24, 16);
        let mut block = [0.0f32; 64];
        let (gw, gh) = grid_dims(24, 16);
        for by in 0..gh {
            for bx in 0..gw {
                extract_block(&img, bx, by, &mut block);
                store_block(&mut out, bx, by, &block);
            }
        }
        assert_eq!(img, out);
    }

    #[test]
    fn level_shift_applied() {
        let img = GrayImage::from_vec(8, 8, vec![128; 64]).unwrap();
        let mut block = [0.0f32; 64];
        extract_block(&img, 0, 0, &mut block);
        assert!(block.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn coef_planar_roundtrip() {
        let mut buf = vec![0.0f32; 16 * 16];
        let qc: [i16; 64] = std::array::from_fn(|i| i as i16 - 32);
        store_coef_planar(&mut buf, 16, 1, 1, &qc);
        let mut back = [0i16; 64];
        load_coef_planar(&buf, 16, 1, 1, &mut back);
        assert_eq!(qc, back);
        // block (0,0) untouched
        assert_eq!(buf[0], 0.0);
    }
}
