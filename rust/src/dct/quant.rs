//! JPEG quantization: the ITU-T T.81 Annex K luma and chroma tables, IJG
//! quality scaling, and block quantize/dequantize.
//!
//! Tables and scaling mirror `python/compile/kernels/ref.py` exactly
//! (including the /4 orthonormal-DCT gain fold and round-half-even), so
//! the CPU lane and the AOT artifacts quantize identically. The chroma
//! table serves the color (YCbCr) pipeline's Cb/Cr planes.

/// ITU-T T.81 Annex K luminance table (quality 50).
pub const JPEG_LUMA_Q50: [u16; 64] = [
    16, 11, 10, 16, 24, 40, 51, 61, //
    12, 12, 14, 19, 26, 58, 60, 55, //
    14, 13, 16, 24, 40, 57, 69, 56, //
    14, 17, 22, 29, 51, 87, 80, 62, //
    18, 22, 37, 56, 68, 109, 103, 77, //
    24, 35, 55, 64, 81, 104, 113, 92, //
    49, 64, 78, 87, 103, 121, 120, 101, //
    72, 92, 95, 98, 112, 100, 103, 99,
];

/// ITU-T T.81 Annex K chrominance table (quality 50).
pub const JPEG_CHROMA_Q50: [u16; 64] = [
    17, 18, 24, 47, 99, 99, 99, 99, //
    18, 21, 26, 66, 99, 99, 99, 99, //
    24, 26, 56, 99, 99, 99, 99, 99, //
    47, 66, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99,
];

/// JPEG's conventional FDCT emits coefficients 4x the orthonormal ones
/// (for N=8); the standard tables assume that scaling, so we fold 1/4 in.
pub const JPEG_DCT_GAIN: f32 = 4.0;

/// IJG quality -> percent scale.
pub fn quality_scale(quality: u8) -> f32 {
    let q = quality.clamp(1, 100) as f32;
    if q < 50.0 {
        5000.0 / q
    } else {
        200.0 - 2.0 * q
    }
}

/// IJG quality scaling of an Annex K base table (values 1..=255).
fn scaled_table(base: &[u16; 64], quality: u8) -> [f32; 64] {
    let scale = quality_scale(quality);
    std::array::from_fn(|i| {
        let v = ((base[i] as f32 * scale + 50.0) / 100.0).floor();
        v.clamp(1.0, 255.0)
    })
}

/// Standard-scaled JPEG luma table at `quality` (values 1..=255).
pub fn quant_table(quality: u8) -> [f32; 64] {
    scaled_table(&JPEG_LUMA_Q50, quality)
}

/// Standard-scaled JPEG chroma table at `quality` (values 1..=255).
pub fn quant_table_chroma(quality: u8) -> [f32; 64] {
    scaled_table(&JPEG_CHROMA_Q50, quality)
}

/// The luma table the orthonormal pipeline actually divides by.
pub fn effective_qtable(quality: u8) -> [f32; 64] {
    let q = quant_table(quality);
    std::array::from_fn(|i| q[i] / JPEG_DCT_GAIN)
}

/// The chroma table the orthonormal color pipeline divides Cb/Cr by.
pub fn effective_qtable_chroma(quality: u8) -> [f32; 64] {
    let q = quant_table_chroma(quality);
    std::array::from_fn(|i| q[i] / JPEG_DCT_GAIN)
}

/// Quantize a coefficient block: `round_half_even(coef / q)` (matches
/// `jnp.round`). Output fits i16 comfortably for 8-bit imagery.
pub fn quantize_block(coef: &[f32; 64], q: &[f32; 64], out: &mut [i16; 64]) {
    for i in 0..64 {
        out[i] = (coef[i] / q[i]).round_ties_even() as i16;
    }
}

/// Dequantize back to coefficient space.
pub fn dequantize_block(qc: &[i16; 64], q: &[f32; 64], out: &mut [f32; 64]) {
    for i in 0..64 {
        out[i] = qc[i] as f32 * q[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q50_is_identity_scale() {
        assert_eq!(quality_scale(50), 100.0);
        let t = quant_table(50);
        for i in 0..64 {
            assert_eq!(t[i], JPEG_LUMA_Q50[i] as f32);
        }
    }

    #[test]
    fn quality_extremes() {
        let q1 = quant_table(1);
        assert!(q1.iter().all(|&v| v == 255.0));
        let q100 = quant_table(100);
        assert!(q100.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn lower_quality_coarser() {
        let q10 = quant_table(10);
        let q90 = quant_table(90);
        for i in 0..64 {
            assert!(q10[i] >= q90[i]);
        }
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let q = effective_qtable(50);
        let mut coef = [0.0f32; 64];
        let mut rng = crate::util::prng::Rng::new(8);
        for v in &mut coef {
            *v = rng.range_f64(-300.0, 300.0) as f32;
        }
        let mut qc = [0i16; 64];
        let mut deq = [0.0f32; 64];
        quantize_block(&coef, &q, &mut qc);
        dequantize_block(&qc, &q, &mut deq);
        for i in 0..64 {
            assert!(
                (deq[i] - coef[i]).abs() <= q[i] / 2.0 + 1e-3,
                "{i}: |{} - {}| > {}",
                deq[i],
                coef[i],
                q[i] / 2.0
            );
        }
    }

    #[test]
    fn round_half_even_semantics() {
        let q = [1.0f32; 64];
        let mut coef = [0.0f32; 64];
        coef[0] = 0.5;
        coef[1] = 1.5;
        coef[2] = -0.5;
        coef[3] = 2.5;
        let mut qc = [0i16; 64];
        quantize_block(&coef, &q, &mut qc);
        assert_eq!(&qc[0..4], &[0, 2, 0, 2]);
    }

    #[test]
    fn matches_python_effective_table_q50() {
        // python: effective_qtable(50)[0][0] = 16/4 = 4.0
        let e = effective_qtable(50);
        assert_eq!(e[0], 4.0);
        assert_eq!(e[63], 99.0 / 4.0);
    }

    #[test]
    fn chroma_q50_is_annex_k() {
        let t = quant_table_chroma(50);
        for i in 0..64 {
            assert_eq!(t[i], JPEG_CHROMA_Q50[i] as f32);
        }
        let e = effective_qtable_chroma(50);
        assert_eq!(e[0], 17.0 / 4.0);
        assert_eq!(e[63], 99.0 / 4.0);
    }

    #[test]
    fn chroma_coarser_than_luma_in_high_bands() {
        // Annex K quantizes chroma high frequencies much harder — that
        // asymmetry is what the color pipeline banks on.
        let luma = quant_table(50);
        let chroma = quant_table_chroma(50);
        assert!(chroma[63] > luma[63] * 0.9);
        let luma_sum: f32 = luma.iter().sum();
        let chroma_sum: f32 = chroma.iter().sum();
        assert!(chroma_sum > luma_sum, "{chroma_sum} vs {luma_sum}");
    }

    #[test]
    fn chroma_quality_extremes() {
        assert!(quant_table_chroma(1).iter().all(|&v| v == 255.0));
        assert!(quant_table_chroma(100).iter().all(|&v| v == 1.0));
    }
}
