//! The Cordic-based Loeffler DCT (the paper's proposed algorithm,
//! Fig. 1): the Loeffler flow graph with its three plane rotators replaced
//! by fixed-point CORDIC shift-add rotators.
//!
//! Defaults (3 micro-rotations, 10 fractional bits) match the Pallas
//! kernel calibration: ~2 dB PSNR below the exact DCT when decoded by a
//! standard IDCT — the Table 3/4 gap.

use super::cordic::{fxp, Rotator};
use super::loeffler::{
    fwd8, inv8, separable_2d, Rotors, ANGLE_EVEN, ANGLE_ODD_A, ANGLE_ODD_B,
};
use super::Transform8x8;

pub const DEFAULT_ITERS: usize = 3;
pub const DEFAULT_FRAC_BITS: u32 = 10;

/// Fixed-point CORDIC rotators for the Loeffler graph.
pub struct CordicRotors {
    ra: Rotator,
    rb: Rotator,
    re: Rotator,
    frac_bits: u32,
}

impl CordicRotors {
    /// Accessors for the lane-wide batch kernels (`dct::batch`).
    pub(crate) fn ra(&self) -> &Rotator {
        &self.ra
    }
    pub(crate) fn rb(&self) -> &Rotator {
        &self.rb
    }
    pub(crate) fn re(&self) -> &Rotator {
        &self.re
    }
    pub(crate) fn frac_bits(&self) -> u32 {
        self.frac_bits
    }

    pub fn new(iters: usize, frac_bits: u32) -> Self {
        CordicRotors {
            ra: Rotator::new(ANGLE_ODD_A, 1.0, iters, frac_bits),
            rb: Rotator::new(ANGLE_ODD_B, 1.0, iters, frac_bits),
            re: Rotator::new(
                ANGLE_EVEN,
                std::f64::consts::SQRT_2,
                iters,
                frac_bits,
            ),
            frac_bits,
        }
    }
}

impl Rotors for CordicRotors {
    fn odd_a(&self, x: f32, y: f32) -> (f32, f32) {
        self.ra.rotate_cw(x, y)
    }
    fn odd_b(&self, x: f32, y: f32) -> (f32, f32) {
        self.rb.rotate_cw(x, y)
    }
    fn even(&self, x: f32, y: f32) -> (f32, f32) {
        self.re.rotate_cw(x, y)
    }
    fn odd_a_inv(&self, x: f32, y: f32) -> (f32, f32) {
        self.ra.rotate_ccw(x, y)
    }
    fn odd_b_inv(&self, x: f32, y: f32) -> (f32, f32) {
        self.rb.rotate_ccw(x, y)
    }
    fn even_inv(&self, x: f32, y: f32) -> (f32, f32) {
        self.re.rotate_ccw(x, y)
    }
    fn grid(&self, v: f32) -> f32 {
        fxp(v, self.frac_bits)
    }
}

/// The paper's algorithm as an 8x8 block transform.
pub struct CordicLoefflerDct {
    rotors: CordicRotors,
    iters: usize,
}

impl CordicLoefflerDct {
    pub fn new(iters: usize, frac_bits: u32) -> Self {
        CordicLoefflerDct {
            rotors: CordicRotors::new(iters, frac_bits),
            iters,
        }
    }

    /// The CORDIC rotators, for the lane-wide batch kernels.
    pub(crate) fn rotors(&self) -> &CordicRotors {
        &self.rotors
    }
}

impl Default for CordicLoefflerDct {
    fn default() -> Self {
        Self::new(DEFAULT_ITERS, DEFAULT_FRAC_BITS)
    }
}

impl Transform8x8 for CordicLoefflerDct {
    fn name(&self) -> &'static str {
        "cordic-loeffler"
    }

    fn forward(&self, block: &mut [f32; 64]) {
        separable_2d(&self.rotors, block, fwd8);
    }

    fn inverse(&self, block: &mut [f32; 64]) {
        separable_2d(&self.rotors, block, inv8);
    }

    fn ops_per_block(&self) -> (usize, usize) {
        // In hardware the rotators are multiplier-free: each micro-rotation
        // is 2 shifts + 2 adds; gain compensation is folded into the
        // quantizer. Here we count the butterfly adds plus the shift-adds,
        // and report the normalization/gain multiplies (10 per 1-D: 8 norm
        // + 2 sqrt2) as the multiply cost.
        let shift_adds = 3 * self.iters * 2; // 3 rotators
        (16 * 10, 16 * (29 + shift_adds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::{dct_matrix, matrix::MatrixDct};
    use crate::util::prng::Rng;

    fn rand_block(seed: u64) -> [f32; 64] {
        let mut rng = Rng::new(seed);
        std::array::from_fn(|_| rng.range_f64(-128.0, 128.0) as f32)
    }

    #[test]
    fn approximates_exact_dct() {
        let c = CordicLoefflerDct::default();
        let m = MatrixDct::new();
        let mut a = rand_block(1);
        let mut b = a;
        c.forward(&mut a);
        m.forward(&mut b);
        // rough approximation bound from the residual rotator angle
        let norm: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 0.3 * norm, "max_err {max_err} norm {norm}");
        // and the approximation must be nonzero (it is the paper's point)
        assert!(max_err > 1e-4);
    }

    #[test]
    fn dc_nearly_exact() {
        // DC path has no rotators: constant block -> DC = 8 * value
        let c = CordicLoefflerDct::default();
        let mut b = [50.0f32; 64];
        c.forward(&mut b);
        assert!((b[0] - 400.0).abs() < 1.0, "DC {}", b[0]);
        for v in &b[1..] {
            assert!(v.abs() < 1.0);
        }
    }

    #[test]
    fn self_roundtrip_small_error() {
        // cordic fwd + cordic inv leaves only fixed-point noise
        let c = CordicLoefflerDct::default();
        let orig = rand_block(2);
        let mut b = orig;
        c.forward(&mut b);
        c.inverse(&mut b);
        for i in 0..64 {
            assert!(
                (b[i] - orig[i]).abs() < 2.0,
                "{i}: {} vs {}",
                b[i],
                orig[i]
            );
        }
    }

    #[test]
    fn mixed_decode_shows_approximation() {
        // cordic fwd + exact inverse leaves the angle error visible — this
        // is exactly the effect the paper's PSNR tables measure.
        let c = CordicLoefflerDct::default();
        let m = MatrixDct::new();
        let orig = rand_block(3);
        let mut b = orig;
        c.forward(&mut b);
        m.inverse(&mut b);
        let max_err = b
            .iter()
            .zip(&orig)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err > 0.05, "approximation invisible: {max_err}");
        assert!(max_err < 40.0, "approximation too large: {max_err}");
    }

    #[test]
    fn more_iters_better_approximation() {
        let m = MatrixDct::new();
        let orig = rand_block(4);
        let mut exact = orig;
        m.forward(&mut exact);
        let err = |iters: usize, fb: u32| -> f32 {
            let c = CordicLoefflerDct::new(iters, fb);
            let mut b = orig;
            c.forward(&mut b);
            b.iter()
                .zip(&exact)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt()
        };
        assert!(err(6, 14) < err(3, 10));
        assert!(err(3, 10) < err(2, 6) * 1.5);
    }

    #[test]
    fn matches_python_fxp_semantics() {
        // spot-check one rotator output against the jnp fxp convention:
        // values land exactly on the 2^-10 grid
        let c = CordicRotors::new(3, 10);
        let (x, y) = c.odd_a(0.123456, -0.654321);
        let s = 1024.0f32;
        assert_eq!(x, (x * s).round_ties_even() / s);
        assert_eq!(y, (y * s).round_ties_even() / s);
    }

    #[test]
    fn basis_vectors_dct_matrix_rows() {
        // impulse through cordic DCT approximates the matrix column
        let c = CordicLoefflerDct::default();
        let d = dct_matrix();
        let mut b = [0.0f32; 64];
        b[0] = 100.0;
        c.forward(&mut b);
        for u in 0..8 {
            let want = d[u][0] * d[0][0] * 100.0;
            // within 15% of the energy scale
            assert!(
                (b[u * 8] - want).abs() < 5.0,
                "u {u}: {} vs {want}",
                b[u * 8]
            );
        }
    }
}
