//! The serial CPU compression pipeline — the paper's "CPU (serial code)"
//! lane: level shift -> blockwise forward transform -> quantize ->
//! dequantize -> standard IDCT -> unshift/clamp, one thread.
//!
//! Since the batched-engine rework the block loop runs on
//! [`BatchEngine`](super::batch::BatchEngine): eight blocks per
//! lane-major SoA batch (scalar tail for non-multiple-of-8 grid widths),
//! with scratch buffers reused from a per-pipeline arena. The arithmetic
//! per block is unchanged — outputs are bit-identical to the historical
//! one-block-at-a-time loop (`tests/batch_parity.rs`).
//!
//! The decoder side is always the exact matrix IDCT (a standards-compliant
//! decoder), matching the Pallas fused kernel, so approximate encoders
//! (Cordic-Loeffler) show their true reconstruction loss.

use crate::codec::encoder::ScanCoefs;
use crate::image::GrayImage;

use super::batch::{BatchEngine, EngineConfig};
use super::blocks::{grid_dims, pad_to_blocks};
use super::quant::effective_qtable;
use super::Variant;

/// Output of a CPU-lane compression run.
pub struct CpuCompressOutput {
    /// Reconstructed image at the original (uncropped) size.
    pub recon: GrayImage,
    /// Quantized coefficients in planar image layout (padded size), f32 —
    /// the same interchange layout the PJRT artifacts emit.
    pub qcoef: Vec<f32>,
    /// The same coefficients in entropy-coding order (zigzag per block),
    /// from the fused `quantize_zigzag_batch` path — what the encoder
    /// consumes directly, skipping the planar round-trip.
    pub scanned: ScanCoefs,
    /// Padded dimensions the coefficients use.
    pub padded_width: usize,
    pub padded_height: usize,
}

/// Output of a fused-only compression run: reconstruction plus the
/// entropy-coding-order coefficients, with no planar f32 interchange
/// buffer ever allocated. This is what the coordinator's workers consume —
/// [`CpuCompressOutput::qcoef`] exists for interchange with planar
/// backends and tooling, not for the serve hot path.
pub struct FusedCompressOutput {
    /// Reconstructed image at the original (uncropped) size.
    pub recon: GrayImage,
    /// Coefficients in entropy-coding order (zigzag per block).
    pub scanned: ScanCoefs,
}

/// Serial compression pipeline with a pluggable forward transform.
pub struct CpuPipeline {
    engine: BatchEngine,
    pub variant: Variant,
    pub quality: u8,
}

impl CpuPipeline {
    pub fn new(variant: Variant, quality: u8) -> Self {
        Self::with_qtable(variant, quality, effective_qtable(quality))
    }

    /// Pipeline with an explicit [`EngineConfig`] (lane width + fxp
    /// precision); [`CpuPipeline::new`] uses the defaults.
    pub fn with_config(
        variant: Variant,
        quality: u8,
        cfg: EngineConfig,
    ) -> Self {
        Self::with_qtable_config(
            variant,
            quality,
            effective_qtable(quality),
            cfg,
        )
    }

    /// Pipeline dividing by an explicit effective table — the color path
    /// passes the chroma table here; [`CpuPipeline::new`] uses luma.
    pub fn with_qtable(
        variant: Variant,
        quality: u8,
        qtable: [f32; 64],
    ) -> Self {
        Self::with_qtable_config(
            variant,
            quality,
            qtable,
            EngineConfig::default(),
        )
    }

    /// Explicit table *and* engine config — the fully general ctor all
    /// the others delegate to.
    pub fn with_qtable_config(
        variant: Variant,
        quality: u8,
        qtable: [f32; 64],
        cfg: EngineConfig,
    ) -> Self {
        CpuPipeline {
            engine: BatchEngine::with_config(variant, qtable, cfg),
            variant,
            quality,
        }
    }

    pub fn transform_name(&self) -> &'static str {
        self.engine.transform_name()
    }

    /// Run the full pipeline over an image (padding internally if needed).
    pub fn compress(&self, img: &GrayImage) -> CpuCompressOutput {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let mut recon = GrayImage::new(padded.width, padded.height);
        let mut qcoef = vec![0.0f32; padded.pixels()];
        let mut scanned = ScanCoefs::zeroed(
            img.width,
            img.height,
            padded.width,
            padded.height,
        );
        self.engine.with_scratch(|s| {
            for by in 0..gh {
                self.engine.forward_quant_row(
                    s,
                    &padded,
                    by,
                    Some(&mut qcoef),
                    by,
                    Some(&mut scanned.data),
                    Some((&mut recon, by)),
                );
            }
        });
        let recon = if (padded.width, padded.height)
            != (img.width, img.height)
        {
            recon.crop(img.width, img.height).expect("crop to original")
        } else {
            recon
        };
        CpuCompressOutput {
            recon,
            qcoef,
            scanned,
            padded_width: padded.width,
            padded_height: padded.height,
        }
    }

    /// Full pipeline without the planar f32 coefficient buffer: recon +
    /// zigzag-order coefficients only. Identical arithmetic to
    /// [`CpuPipeline::compress`]; use this when `qcoef` would be dropped
    /// unread (the coordinator's gray lane).
    pub fn compress_fused(&self, img: &GrayImage) -> FusedCompressOutput {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let mut recon = GrayImage::new(padded.width, padded.height);
        let mut scanned = ScanCoefs::zeroed(
            img.width,
            img.height,
            padded.width,
            padded.height,
        );
        self.engine.with_scratch(|s| {
            for by in 0..gh {
                self.engine.forward_quant_row(
                    s,
                    &padded,
                    by,
                    None,
                    by,
                    Some(&mut scanned.data),
                    Some((&mut recon, by)),
                );
            }
        });
        let recon = if (padded.width, padded.height)
            != (img.width, img.height)
        {
            recon.crop(img.width, img.height).expect("crop to original")
        } else {
            recon
        };
        FusedCompressOutput { recon, scanned }
    }

    /// Forward transform + quantization only (what the entropy encoder
    /// needs); returns planar coefficients at padded size.
    pub fn analyze(&self, img: &GrayImage) -> (Vec<f32>, usize, usize) {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let mut qcoef = vec![0.0f32; padded.pixels()];
        self.engine.with_scratch(|s| {
            for by in 0..gh {
                self.engine.forward_quant_row(
                    s,
                    &padded,
                    by,
                    Some(&mut qcoef),
                    by,
                    None,
                    None,
                );
            }
        });
        (qcoef, padded.width, padded.height)
    }

    /// Forward transform + quantization straight to entropy-coding order
    /// — the fused front half; no planar f32 interchange buffer is
    /// allocated or written at all.
    pub fn analyze_scanned(&self, img: &GrayImage) -> ScanCoefs {
        let padded = pad_to_blocks(img);
        let (_, gh) = grid_dims(padded.width, padded.height);
        let mut scanned = ScanCoefs::zeroed(
            img.width,
            img.height,
            padded.width,
            padded.height,
        );
        self.engine.with_scratch(|s| {
            for by in 0..gh {
                self.engine.forward_quant_row(
                    s,
                    &padded,
                    by,
                    None,
                    by,
                    Some(&mut scanned.data),
                    None,
                );
            }
        });
        scanned
    }

    /// [`CpuPipeline::analyze_scanned`] into a caller-owned buffer. For an
    /// 8-aligned image whose buffer already has capacity this performs no
    /// heap allocation at all (the image is borrowed, not padded-by-copy)
    /// — the steady state `microbench_hotpath` CI-gates at zero allocs.
    pub fn analyze_scanned_into(
        &self,
        img: &GrayImage,
        out: &mut ScanCoefs,
    ) {
        let padded_owned;
        let padded: &GrayImage =
            if img.width % 8 == 0 && img.height % 8 == 0 {
                img
            } else {
                padded_owned = pad_to_blocks(img);
                &padded_owned
            };
        let (_, gh) = grid_dims(padded.width, padded.height);
        out.reset(img.width, img.height, padded.width, padded.height);
        self.engine.with_scratch(|s| {
            for by in 0..gh {
                self.engine.forward_quant_row(
                    s,
                    padded,
                    by,
                    None,
                    by,
                    Some(&mut out.data),
                    None,
                );
            }
        });
    }

    /// Decode planar quantized coefficients back to an image (the decoder
    /// half: dequantize + standard IDCT).
    pub fn decode_coefficients(
        &self,
        qcoef: &[f32],
        padded_width: usize,
        padded_height: usize,
        out_width: usize,
        out_height: usize,
    ) -> GrayImage {
        let (_, gh) = grid_dims(padded_width, padded_height);
        let mut recon = GrayImage::new(padded_width, padded_height);
        self.engine.with_scratch(|s| {
            for by in 0..gh {
                self.engine.decode_row(
                    s,
                    qcoef,
                    padded_width,
                    by,
                    &mut recon,
                    by,
                );
            }
        });
        if (padded_width, padded_height) != (out_width, out_height) {
            recon.crop(out_width, out_height).expect("crop")
        } else {
            recon
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;
    use crate::metrics::psnr;

    #[test]
    fn dct_pipeline_reasonable_psnr() {
        let img = synthetic::lena_like(64, 64, 1);
        let out = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        let p = psnr(&img, &out.recon);
        assert!(p > 30.0, "PSNR {p}");
        assert_eq!(out.recon.width, 64);
    }

    #[test]
    fn cordic_below_dct_psnr() {
        let img = synthetic::lena_like(96, 96, 2);
        let p_dct = psnr(
            &img,
            &CpuPipeline::new(Variant::Dct, 50).compress(&img).recon,
        );
        let p_cor = psnr(
            &img,
            &CpuPipeline::new(Variant::Cordic, 50).compress(&img).recon,
        );
        assert!(p_cor < p_dct, "cordic {p_cor} vs dct {p_dct}");
        let gap = p_dct - p_cor;
        assert!((0.3..8.0).contains(&gap), "gap {gap}");
    }

    #[test]
    fn quality_monotone() {
        let img = synthetic::cablecar_like(64, 64, 3);
        let p10 = psnr(
            &img,
            &CpuPipeline::new(Variant::Dct, 10).compress(&img).recon,
        );
        let p50 = psnr(
            &img,
            &CpuPipeline::new(Variant::Dct, 50).compress(&img).recon,
        );
        let p90 = psnr(
            &img,
            &CpuPipeline::new(Variant::Dct, 90).compress(&img).recon,
        );
        assert!(p10 < p50 && p50 < p90, "{p10} {p50} {p90}");
    }

    #[test]
    fn unaligned_image_pads_and_crops() {
        let img = synthetic::lena_like(30, 21, 4);
        let out = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        assert_eq!((out.recon.width, out.recon.height), (30, 21));
        assert_eq!((out.padded_width, out.padded_height), (32, 24));
        assert!(psnr(&img, &out.recon) > 28.0);
    }

    #[test]
    fn analyze_then_decode_matches_compress() {
        let img = synthetic::lena_like(40, 32, 5);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let full = pipe.compress(&img);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        assert_eq!(qcoef, full.qcoef);
        let recon = pipe.decode_coefficients(&qcoef, pw, ph, 40, 32);
        assert_eq!(recon, full.recon);
    }

    #[test]
    fn scanned_output_matches_planar_rescan() {
        use crate::codec::encoder::ScanCoefs;
        // the fused zigzag stream is exactly the planar buffer re-scanned
        for (w, h) in [(40, 32), (30, 21)] {
            let img = synthetic::lena_like(w, h, 6);
            let pipe = CpuPipeline::new(Variant::Cordic, 50);
            let full = pipe.compress(&img);
            let want = ScanCoefs::from_planar(
                &full.qcoef,
                full.padded_width,
                full.padded_height,
                w,
                h,
            );
            assert_eq!(full.scanned, want);
            assert_eq!(pipe.analyze_scanned(&img), want);
        }
    }

    #[test]
    fn fused_compress_matches_full_compress() {
        for (w, h) in [(40, 32), (30, 21)] {
            let img = synthetic::lena_like(w, h, 6);
            let pipe = CpuPipeline::new(Variant::Dct, 50);
            let full = pipe.compress(&img);
            let fused = pipe.compress_fused(&img);
            assert_eq!(fused.recon, full.recon);
            assert_eq!(fused.scanned, full.scanned);
            // the into-buffer variant matches even when the buffer is
            // reused across differently-shaped runs
            let mut buf = ScanCoefs::zeroed(8, 8, 8, 8);
            pipe.analyze_scanned_into(&img, &mut buf);
            assert_eq!(buf, full.scanned);
        }
    }

    #[test]
    fn loeffler_matches_dct_variant_closely() {
        let img = synthetic::lena_like(48, 48, 6);
        let a = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        let b = CpuPipeline::new(Variant::Loeffler, 50).compress(&img);
        let p = psnr(&a.recon, &b.recon);
        assert!(p > 45.0, "exact-rotator loeffler differs: {p}");
    }
}
