//! Textbook direct 2-D DCT — a scalar transliteration of the paper's
//! equation (6): every output coefficient is a full double sum over the
//! 8x8 block. 4096 multiplies per pass; the slowest possible correct
//! implementation and therefore the reference point of the ablation table
//! (and the most literal reading of "CPU serial code").

use super::Transform8x8;

pub struct NaiveDct {
    /// cos[(2n+1) k pi / 16] table, [k][n].
    cos: [[f32; 8]; 8],
    /// alpha(k) normalization.
    alpha: [f32; 8],
}

impl NaiveDct {
    pub fn new() -> Self {
        let mut cos = [[0.0f32; 8]; 8];
        let mut alpha = [0.0f32; 8];
        for k in 0..8 {
            alpha[k] = if k == 0 {
                (1.0f64 / 2.0f64.sqrt()) as f32
            } else {
                1.0
            };
            for n in 0..8 {
                cos[k][n] = (((2 * n + 1) as f64
                    * k as f64
                    * std::f64::consts::PI
                    / 16.0)
                    .cos()) as f32;
            }
        }
        NaiveDct { cos, alpha }
    }
}

impl Default for NaiveDct {
    fn default() -> Self {
        Self::new()
    }
}

impl Transform8x8 for NaiveDct {
    fn name(&self) -> &'static str {
        "naive"
    }

    /// F(u,v) = 1/4 a(u) a(v) sum_i sum_j f(i,j) cos.. cos..  (paper eq. 6,
    /// orthonormal form for N=M=8).
    fn forward(&self, block: &mut [f32; 64]) {
        let mut out = [0.0f32; 64];
        for u in 0..8 {
            for v in 0..8 {
                let mut acc = 0.0f32;
                for i in 0..8 {
                    for j in 0..8 {
                        acc += block[i * 8 + j]
                            * self.cos[u][i]
                            * self.cos[v][j];
                    }
                }
                out[u * 8 + v] =
                    0.25 * self.alpha[u] * self.alpha[v] * acc;
            }
        }
        *block = out;
    }

    fn inverse(&self, block: &mut [f32; 64]) {
        let mut out = [0.0f32; 64];
        for i in 0..8 {
            for j in 0..8 {
                let mut acc = 0.0f32;
                for u in 0..8 {
                    for v in 0..8 {
                        acc += self.alpha[u]
                            * self.alpha[v]
                            * block[u * 8 + v]
                            * self.cos[u][i]
                            * self.cos[v][j];
                    }
                }
                out[i * 8 + j] = 0.25 * acc;
            }
        }
        *block = out;
    }

    fn ops_per_block(&self) -> (usize, usize) {
        // 64 outputs x (64 mults for the double sum x2 cos + 3 scale)
        (64 * (64 * 2 + 3), 64 * 63)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::dct_matrix;
    use crate::util::prng::Rng;

    fn rand_block(seed: u64) -> [f32; 64] {
        let mut rng = Rng::new(seed);
        let mut b = [0.0f32; 64];
        for v in &mut b {
            *v = rng.range_f64(-128.0, 128.0) as f32;
        }
        b
    }

    /// Matrix-product reference: D B D^T.
    fn matrix_ref(block: &[f32; 64]) -> [f32; 64] {
        let d = dct_matrix();
        let mut tmp = [0.0f64; 64];
        for k in 0..8 {
            for j in 0..8 {
                tmp[k * 8 + j] = (0..8)
                    .map(|n| d[k][n] as f64 * block[n * 8 + j] as f64)
                    .sum();
            }
        }
        let mut out = [0.0f32; 64];
        for k in 0..8 {
            for l in 0..8 {
                out[k * 8 + l] = (0..8)
                    .map(|j| tmp[k * 8 + j] * d[l][j] as f64)
                    .sum::<f64>() as f32;
            }
        }
        out
    }

    #[test]
    fn forward_matches_matrix_form() {
        let t = NaiveDct::new();
        for seed in 0..4 {
            let mut b = rand_block(seed);
            let want = matrix_ref(&b);
            t.forward(&mut b);
            for i in 0..64 {
                assert!((b[i] - want[i]).abs() < 1e-3,
                        "coef {i}: {} vs {}", b[i], want[i]);
            }
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let t = NaiveDct::new();
        let orig = rand_block(9);
        let mut b = orig;
        t.forward(&mut b);
        t.inverse(&mut b);
        for i in 0..64 {
            assert!((b[i] - orig[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn dc_of_constant_block() {
        let t = NaiveDct::new();
        let mut b = [10.0f32; 64];
        t.forward(&mut b);
        assert!((b[0] - 80.0).abs() < 1e-3); // 8 * 10 (orthonormal 2-D)
        for v in &b[1..] {
            assert!(v.abs() < 1e-3);
        }
    }

    #[test]
    fn energy_preserved() {
        let t = NaiveDct::new();
        let orig = rand_block(5);
        let mut b = orig;
        t.forward(&mut b);
        let e_in: f32 = orig.iter().map(|v| v * v).sum();
        let e_out: f32 = b.iter().map(|v| v * v).sum();
        assert!((e_in - e_out).abs() / e_in < 1e-4);
    }
}
