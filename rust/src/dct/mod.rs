//! The transform substrate: four 8x8 DCT implementations (the paper's
//! algorithm menagerie), JPEG quantization, block management and the
//! serial CPU compression pipeline.
//!
//! These are the paper's "CPU (serial code)" lane: scalar Rust, one thread,
//! no SIMD intrinsics — the honest baseline the GPU lane is compared
//! against, exactly as the paper compares serial C against CUDA kernels.
//!
//! * [`naive`]   — textbook O(N^4)-per-block direct 2-D DCT (paper eq. 6)
//! * [`matrix`]  — separable matrix DCT (two 8x8 matmuls per block)
//! * [`loeffler`] — Loeffler flow graph, exact rotations (11 mult/1-D)
//! * [`cordic_loeffler`] — the paper's subject: Loeffler with fixed-point
//!   CORDIC shift-add rotators (paper Fig. 1)
//! * [`cordic_fxp`] — integer fixed-point CORDIC-Loeffler: i32 shift-add
//!   datapath with a runtime precision knob (micro-rotations + fraction
//!   bits, after the Generic-Precision DCT-CORDIC design)
//!
//! [`pipeline`] is the serial one-thread lane exactly as the paper ran it;
//! [`parallel`] fans the same arithmetic over row-band tiles and worker
//! threads (bit-identical output — the coordinator's `CpuParallel` lane);
//! [`color`] orchestrates either lane once per YCbCr plane (luma/chroma
//! quantization tables, 4:4:4/4:2:2/4:2:0 chroma subsampling) for the
//! color workload. Both CPU lanes execute their block loops on
//! [`batch`] — the width-generic lane-major SoA engine (8- or 16-wide,
//! one block per SIMD lane, bit-identical to the scalar sequence at
//! either width; the CPU mirror of the GPU's thread-per-block mapping).
//!
//! All implementations produce *orthonormally scaled* coefficients so they
//! are interchangeable in front of [`quant`] and bit-compatible with the
//! Pallas kernels in `python/compile/kernels/` (same arithmetic, checked
//! by the cross-lane integration tests).

pub mod batch;
pub mod blocks;
pub mod color;
pub mod cordic;
pub mod cordic_fxp;
pub mod cordic_loeffler;
pub mod loeffler;
pub mod matrix;
pub mod naive;
pub mod parallel;
pub mod pipeline;
pub mod planar;
pub mod quant;

/// An 8x8 blockwise 2-D transform. Blocks are row-major `[f32; 64]`.
pub trait Transform8x8: Send + Sync {
    fn name(&self) -> &'static str;

    /// In-place forward 2-D DCT (orthonormal scaling).
    fn forward(&self, block: &mut [f32; 64]);

    /// In-place inverse 2-D DCT.
    fn inverse(&self, block: &mut [f32; 64]);

    /// (multiplies, additions) per 8x8 block for the ablation table.
    fn ops_per_block(&self) -> (usize, usize);
}

/// Transform variant selector shared with the CLI / manifest naming.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Exact separable matrix DCT.
    Dct,
    /// Loeffler flow graph with exact rotators.
    Loeffler,
    /// Cordic-based Loeffler (the paper's proposed algorithm).
    Cordic,
    /// Integer fixed-point CORDIC-Loeffler (shift-add i32 datapath,
    /// precision-parameterized; approximate — PSNR-bound, not bit-exact).
    CordicFxp,
    /// Textbook direct 2-D DCT (only used as a baseline/ablation).
    Naive,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "dct" | "matrix" | "exact" => Some(Variant::Dct),
            "loeffler" => Some(Variant::Loeffler),
            "cordic" | "cordic-loeffler" | "cordic_loeffler" => {
                Some(Variant::Cordic)
            }
            "cordic-fxp" | "cordic_fxp" | "fxp" => {
                Some(Variant::CordicFxp)
            }
            "naive" | "direct" => Some(Variant::Naive),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Dct => "dct",
            Variant::Loeffler => "loeffler",
            Variant::Cordic => "cordic",
            Variant::CordicFxp => "cordic-fxp",
            Variant::Naive => "naive",
        }
    }

    /// Instantiate the transform with default parameters.
    pub fn transform(&self) -> Box<dyn Transform8x8> {
        match self {
            Variant::Dct => Box::new(matrix::MatrixDct::new()),
            Variant::Loeffler => Box::new(loeffler::LoefflerDct::new()),
            Variant::Cordic => {
                Box::new(cordic_loeffler::CordicLoefflerDct::default())
            }
            Variant::CordicFxp => {
                Box::new(cordic_fxp::CordicFxpDct::default())
            }
            Variant::Naive => Box::new(naive::NaiveDct::new()),
        }
    }
}

/// The orthonormal 8-point DCT-II matrix, row-major: `y = D x`.
pub fn dct_matrix() -> [[f32; 8]; 8] {
    let mut d = [[0.0f32; 8]; 8];
    for (k, row) in d.iter_mut().enumerate() {
        let ck = if k == 0 {
            (0.5f64).sqrt()
        } else {
            1.0
        };
        for (n, v) in row.iter_mut().enumerate() {
            *v = (0.5
                * ck
                * ((2 * n + 1) as f64 * k as f64 * std::f64::consts::PI
                    / 16.0)
                    .cos()) as f32;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dct_matrix_orthonormal() {
        let d = dct_matrix();
        for i in 0..8 {
            for j in 0..8 {
                let dot: f32 =
                    (0..8).map(|k| d[i][k] * d[j][k]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-6, "({i},{j}) dot {dot}");
            }
        }
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("DCT"), Some(Variant::Dct));
        assert_eq!(Variant::parse("cordic-loeffler"), Some(Variant::Cordic));
        assert_eq!(Variant::parse("cordic-fxp"), Some(Variant::CordicFxp));
        assert_eq!(Variant::parse("fxp"), Some(Variant::CordicFxp));
        assert_eq!(Variant::parse("x"), None);
        assert_eq!(Variant::Cordic.as_str(), "cordic");
        assert_eq!(Variant::CordicFxp.as_str(), "cordic-fxp");
    }

    #[test]
    fn all_variants_instantiate() {
        for v in [Variant::Dct, Variant::Loeffler, Variant::Cordic,
                  Variant::CordicFxp, Variant::Naive] {
            let t = v.transform();
            assert!(!t.name().is_empty());
        }
    }
}
