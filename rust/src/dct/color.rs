//! The color (YCbCr) compression pipeline: a thin orchestration layer
//! that runs the existing grayscale pipeline once per plane.
//!
//! Flow (`compress`):
//!
//! ```text
//! RGB ──► BT.601 Y/Cb/Cr ──► chroma downsample (4:4:4/4:2:2/4:2:0)
//!     Y  ──► gray pipeline with the *luma*   quant table ─┐
//!     Cb ──► gray pipeline with the *chroma* quant table ─┼─► recon
//!     Cr ──► gray pipeline with the *chroma* quant table ─┘   planes
//! chroma upsample ──► YCbCr → RGB reconstruction
//! ```
//!
//! Every plane runs the exact serial or block-parallel grayscale code
//! path — and therefore the 8-wide batched block engine
//! ([`dct::batch`](super::batch)) those lanes are built on — so the luma
//! plane of a color job is bit-identical to a grayscale job on the same
//! plane (asserted by `tests/color_parity.rs` and the color half of
//! `tests/batch_parity.rs`) and all four transform variants work
//! unchanged. The plane decomposition is also the planar-batch shape the
//! future GPU lane consumes (1 plane for gray, 3 for color).

use crate::codec::encoder::ScanCoefs;
use crate::image::color::ColorImage;
use crate::image::ycbcr::{self, Subsampling};
use crate::image::GrayImage;

use super::batch::EngineConfig;
use super::parallel::ParallelCpuPipeline;
use super::planar::split_ycbcr;
use super::pipeline::{CpuCompressOutput, CpuPipeline, FusedCompressOutput};
use super::quant::{effective_qtable, effective_qtable_chroma};
use super::Variant;

/// Quantized coefficients of one plane (planar layout, padded size).
#[derive(Clone, Debug, PartialEq)]
pub struct PlaneCoef {
    pub qcoef: Vec<f32>,
    /// Pre-padding plane size.
    pub width: usize,
    pub height: usize,
    /// Padded (8-aligned) size the coefficient grid uses.
    pub padded_width: usize,
    pub padded_height: usize,
}

impl PlaneCoef {
    fn from_output(out: &CpuCompressOutput, w: usize, h: usize)
                   -> PlaneCoef {
        PlaneCoef {
            qcoef: out.qcoef.clone(),
            width: w,
            height: h,
            padded_width: out.padded_width,
            padded_height: out.padded_height,
        }
    }
}

/// Output of a color compression run.
pub struct ColorCompressOutput {
    /// Reconstructed RGB image at the original size.
    pub recon: ColorImage,
    /// Full-resolution reconstructed luma plane — bit-identical to the
    /// grayscale pipeline's reconstruction of the Y plane.
    pub recon_y: GrayImage,
    /// Reconstructed chroma planes at their subsampled resolution.
    pub recon_cb: GrayImage,
    pub recon_cr: GrayImage,
    /// Quantized coefficients per plane, in Y/Cb/Cr order.
    pub planes: [PlaneCoef; 3],
    /// The same coefficients in entropy-coding order per plane (the
    /// fused `quantize_zigzag_batch` output the color encoder consumes
    /// directly), Y/Cb/Cr order.
    pub scanned: [ScanCoefs; 3],
}

/// Output of a fused-only color run: RGB + luma reconstructions plus the
/// per-plane zigzag coefficients, with no planar f32 buffers and no
/// [`PlaneCoef`] clones — everything the coordinator's color lane
/// consumes and nothing it drops.
pub struct FusedColorOutput {
    /// Reconstructed RGB image at the original size.
    pub recon: ColorImage,
    /// Full-resolution reconstructed luma plane.
    pub recon_y: GrayImage,
    /// Coefficients in entropy-coding order per plane, Y/Cb/Cr order.
    pub scanned: [ScanCoefs; 3],
}

/// Per-plane executors: the serial or parallel grayscale pipeline, one
/// instance quantizing with the luma table and one with chroma.
enum PlanePipes {
    Serial {
        luma: CpuPipeline,
        chroma: CpuPipeline,
    },
    Parallel {
        luma: ParallelCpuPipeline,
        chroma: ParallelCpuPipeline,
    },
}

/// Color compression pipeline over the CPU lanes.
///
/// # Examples
///
/// Compress a synthetic RGB image at 4:2:0 and check the luma-weighted
/// reconstruction quality:
///
/// ```
/// use cordic_dct::dct::color::ColorPipeline;
/// use cordic_dct::dct::Variant;
/// use cordic_dct::image::synthetic;
/// use cordic_dct::image::ycbcr::Subsampling;
/// use cordic_dct::metrics::color::psnr_color;
///
/// let img = synthetic::lena_like_rgb(32, 32, 7);
/// let pipe = ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420);
/// let out = pipe.compress(&img);
/// assert_eq!((out.recon.width, out.recon.height), (32, 32));
/// assert!(psnr_color(&img, &out.recon).weighted > 25.0);
/// // three planes of fused zigzag coefficients, Y/Cb/Cr order
/// assert_eq!(out.scanned[0].width, 32);
/// assert_eq!(out.scanned[1].width, 16); // 4:2:0 chroma
/// ```
pub struct ColorPipeline {
    pipes: PlanePipes,
    pub variant: Variant,
    pub quality: u8,
    pub subsampling: Subsampling,
}

impl ColorPipeline {
    /// Serial-lane color pipeline.
    pub fn new(
        variant: Variant,
        quality: u8,
        subsampling: Subsampling,
    ) -> Self {
        Self::new_with(variant, quality, subsampling,
                       EngineConfig::default())
    }

    /// Serial-lane color pipeline with an explicit [`EngineConfig`]
    /// (lane width + fxp precision) applied to both plane pipelines.
    pub fn new_with(
        variant: Variant,
        quality: u8,
        subsampling: Subsampling,
        cfg: EngineConfig,
    ) -> Self {
        ColorPipeline {
            pipes: PlanePipes::Serial {
                luma: CpuPipeline::with_qtable_config(
                    variant,
                    quality,
                    effective_qtable(quality),
                    cfg,
                ),
                chroma: CpuPipeline::with_qtable_config(
                    variant,
                    quality,
                    effective_qtable_chroma(quality),
                    cfg,
                ),
            },
            variant,
            quality,
            subsampling,
        }
    }

    /// Parallel-lane color pipeline (`workers == 0` = machine default).
    pub fn parallel(
        variant: Variant,
        quality: u8,
        subsampling: Subsampling,
        workers: usize,
    ) -> Self {
        Self::parallel_with(variant, quality, subsampling, workers,
                            EngineConfig::default())
    }

    /// Parallel-lane color pipeline with an explicit [`EngineConfig`].
    pub fn parallel_with(
        variant: Variant,
        quality: u8,
        subsampling: Subsampling,
        workers: usize,
        cfg: EngineConfig,
    ) -> Self {
        ColorPipeline {
            pipes: PlanePipes::Parallel {
                luma: ParallelCpuPipeline::with_qtable_config(
                    variant,
                    quality,
                    workers,
                    effective_qtable(quality),
                    cfg,
                ),
                chroma: ParallelCpuPipeline::with_qtable_config(
                    variant,
                    quality,
                    workers,
                    effective_qtable_chroma(quality),
                    cfg,
                ),
            },
            variant,
            quality,
            subsampling,
        }
    }

    pub fn is_parallel(&self) -> bool {
        matches!(self.pipes, PlanePipes::Parallel { .. })
    }

    fn compress_plane(&self, plane: &GrayImage, chroma: bool)
                      -> CpuCompressOutput {
        match &self.pipes {
            PlanePipes::Serial { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.compress(plane)
            }
            PlanePipes::Parallel { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.compress(plane)
            }
        }
    }

    fn compress_plane_fused(&self, plane: &GrayImage, chroma: bool)
                            -> FusedCompressOutput {
        match &self.pipes {
            PlanePipes::Serial { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.compress_fused(plane)
            }
            PlanePipes::Parallel { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.compress_fused(plane)
            }
        }
    }

    fn scan_plane(&self, plane: &GrayImage, chroma: bool) -> ScanCoefs {
        match &self.pipes {
            PlanePipes::Serial { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.analyze_scanned(plane)
            }
            PlanePipes::Parallel { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.analyze_scanned(plane)
            }
        }
    }

    fn analyze_plane(&self, plane: &GrayImage, chroma: bool)
                     -> (Vec<f32>, usize, usize) {
        match &self.pipes {
            PlanePipes::Serial { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.analyze(plane)
            }
            PlanePipes::Parallel { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.analyze(plane)
            }
        }
    }

    fn decode_plane(&self, p: &PlaneCoef, chroma: bool) -> GrayImage {
        match &self.pipes {
            PlanePipes::Serial { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.decode_coefficients(
                    &p.qcoef,
                    p.padded_width,
                    p.padded_height,
                    p.width,
                    p.height,
                )
            }
            PlanePipes::Parallel { luma, chroma: c } => {
                let pipe = if chroma { c } else { luma };
                pipe.decode_coefficients(
                    &p.qcoef,
                    p.padded_width,
                    p.padded_height,
                    p.width,
                    p.height,
                )
            }
        }
    }

    /// Split an RGB image into the three planes the pipeline compresses:
    /// full-resolution Y plus subsampled Cb/Cr. Delegates to
    /// [`split_ycbcr`](super::planar::split_ycbcr), the shared
    /// decomposition the GPU lane's
    /// [`PlanarBatch`](super::planar::PlanarBatch) is built from — so
    /// every lane starts a color job from bit-identical planes.
    pub fn split_planes(&self, img: &ColorImage)
                        -> (GrayImage, GrayImage, GrayImage) {
        split_ycbcr(img, self.subsampling)
    }

    /// Full pipeline: convert, subsample, compress each plane, upsample
    /// and reassemble the RGB reconstruction.
    pub fn compress(&self, img: &ColorImage) -> ColorCompressOutput {
        let (y, cb, cr) = self.split_planes(img);
        let oy = self.compress_plane(&y, false);
        let ocb = self.compress_plane(&cb, true);
        let ocr = self.compress_plane(&cr, true);
        let cb_full = ycbcr::upsample(
            &ocb.recon,
            self.subsampling,
            img.width,
            img.height,
        );
        let cr_full = ycbcr::upsample(
            &ocr.recon,
            self.subsampling,
            img.width,
            img.height,
        );
        let recon = ycbcr::ycbcr_to_rgb(&oy.recon, &cb_full, &cr_full)
            .expect("planes upsampled to matching size");
        ColorCompressOutput {
            recon,
            planes: [
                PlaneCoef::from_output(&oy, y.width, y.height),
                PlaneCoef::from_output(&ocb, cb.width, cb.height),
                PlaneCoef::from_output(&ocr, cr.width, cr.height),
            ],
            scanned: [oy.scanned, ocb.scanned, ocr.scanned],
            recon_y: oy.recon,
            recon_cb: ocb.recon,
            recon_cr: ocr.recon,
        }
    }

    /// Full pipeline without any planar f32 coefficient buffers:
    /// per-plane [`CpuPipeline::compress_fused`] plus the same upsample/
    /// reassemble as [`ColorPipeline::compress`]. Identical recon and
    /// scanned output; this is the coordinator's color hot path.
    pub fn compress_fused(&self, img: &ColorImage) -> FusedColorOutput {
        let (y, cb, cr) = self.split_planes(img);
        let oy = self.compress_plane_fused(&y, false);
        let ocb = self.compress_plane_fused(&cb, true);
        let ocr = self.compress_plane_fused(&cr, true);
        let cb_full = ycbcr::upsample(
            &ocb.recon,
            self.subsampling,
            img.width,
            img.height,
        );
        let cr_full = ycbcr::upsample(
            &ocr.recon,
            self.subsampling,
            img.width,
            img.height,
        );
        let recon = ycbcr::ycbcr_to_rgb(&oy.recon, &cb_full, &cr_full)
            .expect("planes upsampled to matching size");
        FusedColorOutput {
            recon,
            recon_y: oy.recon,
            scanned: [oy.scanned, ocb.scanned, ocr.scanned],
        }
    }

    /// Forward transform + quantization straight to entropy-coding order
    /// per plane (Y/Cb/Cr) — no reconstruction, no planar buffers; the
    /// recon-free serve path that never computes PSNR runs on this.
    pub fn analyze_scanned(&self, img: &ColorImage) -> [ScanCoefs; 3] {
        let (y, cb, cr) = self.split_planes(img);
        [
            self.scan_plane(&y, false),
            self.scan_plane(&cb, true),
            self.scan_plane(&cr, true),
        ]
    }

    /// Forward transform + quantization only (what the entropy encoder
    /// needs), per plane in Y/Cb/Cr order.
    pub fn analyze(&self, img: &ColorImage) -> [PlaneCoef; 3] {
        let (y, cb, cr) = self.split_planes(img);
        let plane = |img: &GrayImage, chroma: bool| {
            let (qcoef, pw, ph) = self.analyze_plane(img, chroma);
            PlaneCoef {
                qcoef,
                width: img.width,
                height: img.height,
                padded_width: pw,
                padded_height: ph,
            }
        };
        [plane(&y, false), plane(&cb, true), plane(&cr, true)]
    }

    /// Decode quantized plane coefficients back to an RGB image (the
    /// decoder half: dequantize + IDCT per plane, upsample, convert).
    pub fn decode_coefficients(&self, planes: &[PlaneCoef; 3])
                               -> ColorImage {
        let y = self.decode_plane(&planes[0], false);
        let cb = self.decode_plane(&planes[1], true);
        let cr = self.decode_plane(&planes[2], true);
        let cb_full =
            ycbcr::upsample(&cb, self.subsampling, y.width, y.height);
        let cr_full =
            ycbcr::upsample(&cr, self.subsampling, y.width, y.height);
        ycbcr::ycbcr_to_rgb(&y, &cb_full, &cr_full)
            .expect("planes upsampled to matching size")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;
    use crate::metrics::color::psnr_color;

    #[test]
    fn color_pipeline_reasonable_psnr() {
        let img = synthetic::lena_like_rgb(64, 64, 1);
        for mode in Subsampling::ALL {
            let out = ColorPipeline::new(Variant::Dct, 50, mode)
                .compress(&img);
            let p = psnr_color(&img, &out.recon);
            assert!(p.weighted > 28.0, "{} -> {:.2}", mode.as_str(),
                    p.weighted);
            assert_eq!(
                (out.recon.width, out.recon.height),
                (64, 64)
            );
        }
    }

    #[test]
    fn subsampling_shrinks_chroma_planes() {
        let img = synthetic::cablecar_like_rgb(30, 21, 2);
        let out =
            ColorPipeline::new(Variant::Dct, 50, Subsampling::S420)
                .compress(&img);
        assert_eq!((out.planes[0].width, out.planes[0].height), (30, 21));
        assert_eq!((out.planes[1].width, out.planes[1].height), (15, 11));
        assert_eq!(out.planes[1].padded_width, 16);
        assert_eq!((out.recon.width, out.recon.height), (30, 21));
    }

    #[test]
    fn luma_untouched_by_chroma_decimation() {
        let img = synthetic::lena_like_rgb(96, 96, 3);
        let out444 =
            ColorPipeline::new(Variant::Dct, 50, Subsampling::S444)
                .compress(&img);
        let out420 =
            ColorPipeline::new(Variant::Dct, 50, Subsampling::S420)
                .compress(&img);
        // the luma path never sees the chroma decimation: plane output
        // is bit-identical
        assert_eq!(out444.recon_y, out420.recon_y);
        assert_eq!(out444.planes[0], out420.planes[0]);
        // full-resolution chroma should not lose to decimated chroma by
        // more than conversion-rounding noise
        let p444 = psnr_color(&img, &out444.recon);
        let p420 = psnr_color(&img, &out420.recon);
        assert!(
            p444.weighted >= p420.weighted - 0.75,
            "{} vs {}",
            p444.weighted,
            p420.weighted
        );
    }

    #[test]
    fn analyze_matches_compress() {
        let img = synthetic::lena_like_rgb(40, 32, 5);
        let pipe =
            ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420);
        let out = pipe.compress(&img);
        let planes = pipe.analyze(&img);
        assert_eq!(planes, out.planes);
        let recon = pipe.decode_coefficients(&planes);
        assert_eq!(recon, out.recon);
    }

    #[test]
    fn parallel_matches_serial() {
        let img = synthetic::cablecar_like_rgb(48, 40, 7);
        for mode in Subsampling::ALL {
            let ser = ColorPipeline::new(Variant::Cordic, 50, mode)
                .compress(&img);
            let par =
                ColorPipeline::parallel(Variant::Cordic, 50, mode, 3)
                    .compress(&img);
            assert_eq!(ser.planes, par.planes, "{}", mode.as_str());
            assert_eq!(ser.scanned, par.scanned);
            assert_eq!(ser.recon, par.recon);
            assert_eq!(ser.recon_y, par.recon_y);
        }
    }

    #[test]
    fn fused_color_matches_full_compress() {
        let img = synthetic::lena_like_rgb(40, 21, 8);
        for parallel in [false, true] {
            let pipe = if parallel {
                ColorPipeline::parallel(
                    Variant::Cordic,
                    50,
                    Subsampling::S420,
                    2,
                )
            } else {
                ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420)
            };
            let full = pipe.compress(&img);
            let fused = pipe.compress_fused(&img);
            assert_eq!(fused.recon, full.recon, "parallel={parallel}");
            assert_eq!(fused.recon_y, full.recon_y);
            assert_eq!(fused.scanned, full.scanned);
            assert_eq!(pipe.analyze_scanned(&img), full.scanned);
        }
    }
}
