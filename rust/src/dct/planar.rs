//! Planar-batch job representation: the uniform shape every lane
//! consumes, one plane for grayscale and three (Y/Cb/Cr) for color.
//!
//! ```text
//!                 gray job                      color job
//!            ┌───────────────┐       ┌─────┐ ┌────┐ ┌────┐
//!            │   Y (w x h)   │       │  Y  │ │ Cb │ │ Cr │   N ∈ {1, 3}
//!            └───────────────┘       │ wxh │ │cwxch│ │cwxch│  planes
//!                                    └─────┘ └────┘ └────┘
//!                  │                        │
//!                  ▼ pad_to_blocks (8-aligned, edge replication)
//!            ┌────────────────────────────────────────────┐
//!            │ per plane: block grid gw x gh, walked in   │
//!            │ BlockBatch8 gathers (8 blocks per batch,   │
//!            │ lane-major SoA — see dct::batch)           │
//!            └────────────────────────────────────────────┘
//! ```
//!
//! (This layout diagram is promoted into `ARCHITECTURE.md` — keep the
//! two copies in sync.)
//!
//! [`PlanarBatch`] is what `runtime::Executor` accepts: the CPU lanes'
//! [`ColorPipeline`](super::color::ColorPipeline) produces the identical
//! plane decomposition through [`split_ycbcr`], so a GPU-lane job and a
//! CPU-lane job start from bit-identical planes. Each plane carries its
//! quantization role ([`PlaneRole`]) — luma planes divide by the Annex K
//! luma table, chroma planes by the chroma table — and the planes are
//! independent until reassembly ([`PlanarBatch::reassemble_color`]), so
//! the executor may run them in parallel.

use anyhow::Result;

use crate::image::color::ColorImage;
use crate::image::ycbcr::{self, Subsampling};
use crate::image::GrayImage;

use super::blocks::{align8, grid_dims, pad_to_blocks};

/// Which quantization table a plane runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlaneRole {
    /// Full-resolution luminance (also the single plane of a gray job).
    Luma,
    /// Subsampled Cb/Cr chrominance.
    Chroma,
}

/// One plane of a planar batch at its natural (pre-padding) resolution.
/// The 8-aligned padded form (edge replication — the exact
/// `pad_to_blocks` the CPU pipelines apply, so padded pixels match
/// across lanes) is computed on demand by [`Plane::padded`]: the stub
/// backend pads inside the CPU pipeline, so only the PJRT path
/// materializes it.
#[derive(Clone, Debug)]
pub struct Plane {
    /// The plane at its natural (pre-padding) resolution.
    pub image: GrayImage,
    pub role: PlaneRole,
}

impl Plane {
    pub fn new(image: GrayImage, role: PlaneRole) -> Plane {
        Plane { image, role }
    }

    /// 8-aligned padded plane the block grid runs over (edge
    /// replication), materialized on demand.
    pub fn padded(&self) -> GrayImage {
        pad_to_blocks(&self.image)
    }

    /// Block-grid dimensions of the padded plane.
    pub fn grid(&self) -> (usize, usize) {
        let (pw, ph) = self.padded_dims();
        grid_dims(pw, ph)
    }

    /// Padded (8-aligned) plane size.
    pub fn padded_dims(&self) -> (usize, usize) {
        (align8(self.image.width), align8(self.image.height))
    }
}

/// Split an RGB image into the three planes every lane compresses:
/// full-resolution Y plus subsampled Cb/Cr (BT.601, box downsample).
/// This is THE plane decomposition — the CPU color pipeline and the
/// GPU-lane planar batch both call it, so parity starts at the input.
pub fn split_ycbcr(
    img: &ColorImage,
    subsampling: Subsampling,
) -> (GrayImage, GrayImage, GrayImage) {
    let (y, cb, cr) = ycbcr::rgb_to_ycbcr(img);
    (
        y,
        ycbcr::downsample(&cb, subsampling),
        ycbcr::downsample(&cr, subsampling),
    )
}

/// A batch of 1 (gray) or 3 (YCbCr) planes — the uniform job shape the
/// runtime executor consumes, built on `dct::batch::BlockBatch8` as the
/// block-gather unit (every plane's block grid is walked in 8-wide
/// lane-major batches by whichever backend runs it).
#[derive(Clone, Debug)]
pub struct PlanarBatch {
    planes: Vec<Plane>,
    /// Original image size (the size reconstruction crops back to).
    pub width: usize,
    pub height: usize,
    /// Chroma subsampling of a color batch; `None` for gray.
    pub subsampling: Option<Subsampling>,
}

impl PlanarBatch {
    /// Single-plane batch from a grayscale image.
    pub fn from_gray(img: &GrayImage) -> PlanarBatch {
        PlanarBatch {
            width: img.width,
            height: img.height,
            subsampling: None,
            planes: vec![Plane::new(img.clone(), PlaneRole::Luma)],
        }
    }

    /// Three-plane batch from an RGB image: BT.601 split + chroma
    /// subsampling, identical to the CPU color pipeline's decomposition.
    pub fn from_color(
        img: &ColorImage,
        subsampling: Subsampling,
    ) -> PlanarBatch {
        let (y, cb, cr) = split_ycbcr(img, subsampling);
        PlanarBatch {
            width: img.width,
            height: img.height,
            subsampling: Some(subsampling),
            planes: vec![
                Plane::new(y, PlaneRole::Luma),
                Plane::new(cb, PlaneRole::Chroma),
                Plane::new(cr, PlaneRole::Chroma),
            ],
        }
    }

    pub fn planes(&self) -> &[Plane] {
        &self.planes
    }

    pub fn len(&self) -> usize {
        self.planes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.planes.is_empty()
    }

    pub fn is_color(&self) -> bool {
        self.planes.len() == 3
    }

    /// Padded shapes (h, w) per plane — what artifact lookup keys on.
    pub fn padded_shapes(&self) -> Vec<(usize, usize)> {
        self.planes
            .iter()
            .map(|p| {
                let (pw, ph) = p.padded_dims();
                (ph, pw)
            })
            .collect()
    }

    /// Reassemble reconstructed planes (Y full-res, Cb/Cr at their
    /// subsampled size) back into an RGB image — the exact upsample +
    /// BT.601 conversion the CPU color pipeline performs.
    pub fn reassemble_color(
        &self,
        recon_y: &GrayImage,
        recon_cb: &GrayImage,
        recon_cr: &GrayImage,
    ) -> Result<ColorImage> {
        let sub = self
            .subsampling
            .ok_or_else(|| anyhow::anyhow!("gray batch has no RGB form"))?;
        let cb_full =
            ycbcr::upsample(recon_cb, sub, self.width, self.height);
        let cr_full =
            ycbcr::upsample(recon_cr, sub, self.width, self.height);
        ycbcr::ycbcr_to_rgb(recon_y, &cb_full, &cr_full)
    }

    /// Expected padded plane shapes for a color image of `w x h` under a
    /// subsampling mode (used for artifact-coverage checks without
    /// building the batch).
    pub fn color_padded_shapes(
        w: usize,
        h: usize,
        subsampling: Subsampling,
    ) -> [(usize, usize); 3] {
        let (cw, ch) = subsampling.chroma_dims(w, h);
        [
            (align8(h), align8(w)),
            (align8(ch), align8(cw)),
            (align8(ch), align8(cw)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    #[test]
    fn gray_batch_is_one_luma_plane() {
        let img = synthetic::lena_like(30, 21, 1);
        let b = PlanarBatch::from_gray(&img);
        assert_eq!(b.len(), 1);
        assert!(!b.is_color());
        assert_eq!(b.planes()[0].role, PlaneRole::Luma);
        assert_eq!(b.planes()[0].image, img);
        assert_eq!(b.planes()[0].padded_dims(), (32, 24));
        assert_eq!(b.planes()[0].grid(), (4, 3));
        assert_eq!(b.padded_shapes(), vec![(24, 32)]);
    }

    #[test]
    fn color_batch_matches_color_pipeline_split() {
        use crate::dct::color::ColorPipeline;
        use crate::dct::Variant;
        let img = synthetic::lena_like_rgb(30, 21, 2);
        let b = PlanarBatch::from_color(&img, Subsampling::S420);
        assert_eq!(b.len(), 3);
        assert!(b.is_color());
        let pipe =
            ColorPipeline::new(Variant::Dct, 50, Subsampling::S420);
        let (y, cb, cr) = pipe.split_planes(&img);
        assert_eq!(b.planes()[0].image, y);
        assert_eq!(b.planes()[1].image, cb);
        assert_eq!(b.planes()[2].image, cr);
        assert_eq!(b.planes()[1].role, PlaneRole::Chroma);
        assert_eq!(
            b.padded_shapes(),
            PlanarBatch::color_padded_shapes(30, 21, Subsampling::S420)
                .to_vec()
        );
    }

    #[test]
    fn reassemble_matches_pipeline_reassembly() {
        let img = synthetic::cablecar_like_rgb(30, 21, 3);
        let b = PlanarBatch::from_color(&img, Subsampling::S420);
        // identity "reconstruction": reassembling the split planes is the
        // same RGB round-trip the color pipeline performs
        let rgb = b
            .reassemble_color(
                &b.planes()[0].image,
                &b.planes()[1].image,
                &b.planes()[2].image,
            )
            .unwrap();
        assert_eq!((rgb.width, rgb.height), (30, 21));
        let gray = PlanarBatch::from_gray(&synthetic::lena_like(8, 8, 1));
        assert!(gray
            .reassemble_color(
                &gray.planes()[0].image,
                &gray.planes()[0].image,
                &gray.planes()[0].image,
            )
            .is_err());
    }
}
