//! Batched 8-wide SIMD-style block engine — the CPU lanes' answer to the
//! GPU's thread-per-block mapping.
//!
//! The scalar pipelines walk the block grid one 8x8 block at a time
//! through a `Box<dyn Transform8x8>` virtual call, which stops the
//! autovectorizer at the hottest loop in the crate. This module
//! restructures the loop into a *lane-major structure-of-arrays* batch:
//! eight neighbouring blocks ride together, one block per SIMD lane, and
//! every transform step is expressed as an `[f32; 8]`-element operation
//! the compiler can map directly onto vector instructions.
//!
//! ```text
//!            scalar layout (AoS)              lane-major SoA (BlockBatch8)
//!   block 0: [e0 e1 e2 ... e63]        data[0]  = [e0 of blocks 0..8]
//!   block 1: [e0 e1 e2 ... e63]   ==>  data[1]  = [e1 of blocks 0..8]
//!   ...                                ...
//!   block 7: [e0 e1 e2 ... e63]        data[63] = [e63 of blocks 0..8]
//! ```
//!
//! (This layout diagram is promoted into `ARCHITECTURE.md` — keep the
//! two copies in sync.)
//!
//! `data[i]` holds element `i` (row-major position within the 8x8 block)
//! of all eight blocks, so one [`Lanes`] add/mul advances the same
//! flow-graph edge of eight independent blocks at once.
//!
//! **Bit-exactness.** Every lane performs *exactly* the scalar op
//! sequence of the serial pipeline — same IEEE f32 adds/muls/divides in
//! the same order, per block — because (a) the Loeffler/matrix lane code
//! is a line-for-line mirror of the scalar flow graph with each `f32`
//! widened to [`Lanes`], (b) the exact rotators delegate per lane to the
//! scalar [`Rotors`] methods, and (c) the CORDIC rotators run the same
//! fixed-point grid (`fxp`) per lane. Elementwise IEEE arithmetic is
//! deterministic, so `qcoef` and the reconstruction are bit-identical to
//! the scalar path (locked by `tests/batch_parity.rs`).
//!
//! [`BatchEngine`] is the monomorphized pipeline core both
//! [`CpuPipeline`](super::pipeline::CpuPipeline) and
//! [`ParallelCpuPipeline`](super::parallel::ParallelCpuPipeline) (and
//! through them the per-plane color pipeline) run on: it walks each block
//! row in batches of [`LANES`], falls back to the scalar path for the
//! `grid_width % 8` tail, and reuses [`BlockScratch`] buffers from a
//! per-pipeline [`ScratchPool`] arena instead of allocating per call.

use std::sync::Mutex;

use crate::codec::zigzag::{scan as zigzag_scan, INV_ZIGZAG, ZIGZAG};
use crate::image::GrayImage;

use super::blocks::{
    extract_block, load_coef_planar, store_block, store_coef_planar, BLOCK,
    LEVEL_SHIFT,
};
use super::cordic::fxp;
use super::cordic_loeffler::{CordicLoefflerDct, CordicRotors};
use super::loeffler::{
    ExactRotors, LoefflerDct, Rotors, INV_SQRT8, SQRT2, SQRT8,
};
use super::matrix::MatrixDct;
use super::naive::NaiveDct;
use super::quant::{dequantize_block, quantize_block};
use super::{Transform8x8, Variant};

/// Number of blocks per batch — one block per SIMD lane.
pub const LANES: usize = 8;

/// An 8-wide lane vector: one `f32` per block in the batch. All
/// arithmetic is elementwise, so lane `l` sees exactly the scalar op
/// sequence of block `l`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Lanes(pub [f32; LANES]);

impl Lanes {
    pub const ZERO: Lanes = Lanes([0.0; LANES]);

    /// Broadcast a scalar constant to all lanes.
    #[inline]
    pub fn splat(v: f32) -> Lanes {
        Lanes([v; LANES])
    }
}

impl std::ops::Add for Lanes {
    type Output = Lanes;
    #[inline]
    fn add(self, rhs: Lanes) -> Lanes {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] + rhs.0[l];
        }
        Lanes(out)
    }
}

impl std::ops::Sub for Lanes {
    type Output = Lanes;
    #[inline]
    fn sub(self, rhs: Lanes) -> Lanes {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] - rhs.0[l];
        }
        Lanes(out)
    }
}

/// Scale every lane by the same scalar (mirrors `x * c` in scalar code —
/// the only multiply shape the lane kernels need; elementwise
/// `Lanes * Lanes` is deliberately absent until a kernel requires it).
impl std::ops::Mul<f32> for Lanes {
    type Output = Lanes;
    #[inline]
    fn mul(self, rhs: f32) -> Lanes {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] * rhs;
        }
        Lanes(out)
    }
}

/// Lane-major SoA batch: element `i` of all [`LANES`] blocks lives in
/// `data[i]` (see the module-level layout diagram).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockBatch8 {
    pub data: [Lanes; 64],
}

impl BlockBatch8 {
    pub fn zeroed() -> BlockBatch8 {
        BlockBatch8 {
            data: [Lanes::ZERO; 64],
        }
    }

    /// Copy lane `l` out as a scalar row-major block.
    #[inline]
    pub fn extract_lane(&self, l: usize) -> [f32; 64] {
        std::array::from_fn(|i| self.data[i].0[l])
    }

    /// Overwrite lane `l` from a scalar row-major block.
    #[inline]
    pub fn insert_lane(&mut self, l: usize, block: &[f32; 64]) {
        for i in 0..64 {
            self.data[i].0[l] = block[i];
        }
    }
}

impl Default for BlockBatch8 {
    fn default() -> Self {
        Self::zeroed()
    }
}

/// Quantized-coefficient batch in the same lane-major layout
/// (`data[i][l]` = coefficient `i` of block `l`).
#[derive(Clone, Debug, PartialEq)]
pub struct QBatch8 {
    pub data: [[i16; LANES]; 64],
}

impl QBatch8 {
    pub fn zeroed() -> QBatch8 {
        QBatch8 {
            data: [[0i16; LANES]; 64],
        }
    }
}

impl Default for QBatch8 {
    fn default() -> Self {
        Self::zeroed()
    }
}

// ---------------------------------------------------------------------------
// Gather / scatter between planar images and the lane-major batch
// ---------------------------------------------------------------------------

/// Gather blocks `(bx0..bx0+n, by)` of an 8-aligned image into the batch,
/// applying the -128 level shift (lane `l` = block `bx0 + l`). Inactive
/// lanes (`l >= n`) are zeroed so tail batches stay deterministic.
pub fn gather(
    batch: &mut BlockBatch8,
    img: &GrayImage,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=LANES).contains(&n));
    let w = img.width;
    for l in 0..n {
        for r in 0..BLOCK {
            let src = (by * BLOCK + r) * w + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                batch.data[r * BLOCK + c].0[l] =
                    img.data[src + c] as f32 - LEVEL_SHIFT;
            }
        }
    }
    for e in batch.data.iter_mut() {
        for v in e.0.iter_mut().skip(n) {
            *v = 0.0;
        }
    }
}

/// Scatter the first `n` lanes back into the image as reconstructed
/// pixels (un-shift, clamp, round — the exact scalar `store_block` math).
pub fn scatter_blocks(
    batch: &BlockBatch8,
    img: &mut GrayImage,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=LANES).contains(&n));
    let w = img.width;
    for l in 0..n {
        for r in 0..BLOCK {
            let dst = (by * BLOCK + r) * w + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                img.data[dst + c] = (batch.data[r * BLOCK + c].0[l]
                    + LEVEL_SHIFT)
                    .clamp(0.0, 255.0)
                    .round() as u8;
            }
        }
    }
}

/// Scatter the first `n` quantized lanes into a planar f32 coefficient
/// buffer (the PJRT interchange layout), blocks `(bx0..bx0+n, by)`.
pub fn scatter_coef(
    qb: &QBatch8,
    buf: &mut [f32],
    width: usize,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=LANES).contains(&n));
    for l in 0..n {
        for r in 0..BLOCK {
            let dst = (by * BLOCK + r) * width + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                buf[dst + c] = qb.data[r * BLOCK + c][l] as f32;
            }
        }
    }
}

/// Scatter the first `n` lanes of a *scan-ordered* quantized batch (the
/// [`quantize_zigzag_batch`] output) into a planar f32 coefficient
/// buffer. Same values as [`scatter_coef`] over the row-major batch —
/// only the source indexing differs.
pub fn scatter_coef_scan(
    qb: &QBatch8,
    buf: &mut [f32],
    width: usize,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=LANES).contains(&n));
    for l in 0..n {
        for r in 0..BLOCK {
            let dst = (by * BLOCK + r) * width + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                buf[dst + c] =
                    qb.data[INV_ZIGZAG[r * BLOCK + c]][l] as f32;
            }
        }
    }
}

/// Scatter the first `n` lanes of a scan-ordered quantized batch into a
/// contiguous entropy-coding buffer: block `(bx0 + l, by)` lands at
/// `((by * grid_w + bx0 + l) * 64)..+64`, already in zigzag order — the
/// layout [`crate::codec::encoder::ScanCoefs`] carries straight into the
/// entropy encoder.
pub fn scatter_scan(
    qb: &QBatch8,
    scanned: &mut [i16],
    grid_w: usize,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=LANES).contains(&n));
    for l in 0..n {
        let base = (by * grid_w + bx0 + l) * 64;
        for k in 0..64 {
            scanned[base + k] = qb.data[k][l];
        }
    }
}

/// Lane-wide dequantize of a *scan-ordered* quantized batch back to a
/// row-major coefficient batch — the exact scalar [`dequantize_block`]
/// multiplies (elementwise, so storage order cannot change the values).
pub fn dequantize_scan_batch(
    qb: &QBatch8,
    q: &[f32; 64],
    out: &mut BlockBatch8,
) {
    for (k, &i) in ZIGZAG.iter().enumerate() {
        let qi = q[i];
        for l in 0..LANES {
            out.data[i].0[l] = qb.data[k][l] as f32 * qi;
        }
    }
}

/// Gather `n` blocks of a planar f32 coefficient buffer into the
/// quantized batch (inverse of [`scatter_coef`]); inactive lanes zeroed.
pub fn gather_coef(
    buf: &[f32],
    width: usize,
    bx0: usize,
    by: usize,
    n: usize,
    qb: &mut QBatch8,
) {
    debug_assert!((1..=LANES).contains(&n));
    for l in 0..n {
        for r in 0..BLOCK {
            let src = (by * BLOCK + r) * width + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                qb.data[r * BLOCK + c][l] =
                    buf[src + c].round_ties_even() as i16;
            }
        }
    }
    for e in qb.data.iter_mut() {
        for v in e.iter_mut().skip(n) {
            *v = 0;
        }
    }
}

// ---------------------------------------------------------------------------
// Lane-wide quantization
// ---------------------------------------------------------------------------

/// Lane-wide quantize: `round_half_even(coef / q)` per lane — the exact
/// scalar [`quantize_block`] math, eight blocks at a time.
pub fn quantize_batch(batch: &BlockBatch8, q: &[f32; 64], out: &mut QBatch8) {
    for i in 0..64 {
        let qi = q[i];
        let lanes = &batch.data[i].0;
        for l in 0..LANES {
            out.data[i][l] = (lanes[l] / qi).round_ties_even() as i16;
        }
    }
}

/// Fused quantize→zigzag: quantize the batch and emit each lane's
/// coefficients already in zigzag scan order (`out.data[k][l]` is scan
/// position `k` of block `l`) — the symbolization front half without the
/// intermediate row-major store. Values are bit-identical to
/// `quantize_block` followed by `zigzag::scan` per block.
pub fn quantize_zigzag_batch(
    batch: &BlockBatch8,
    q: &[f32; 64],
    out: &mut QBatch8,
) {
    for (k, &i) in ZIGZAG.iter().enumerate() {
        let qi = q[i];
        let lanes = &batch.data[i].0;
        for l in 0..LANES {
            out.data[k][l] = (lanes[l] / qi).round_ties_even() as i16;
        }
    }
}

/// Lane-wide dequantize back to coefficient space (exact scalar
/// [`dequantize_block`] math).
pub fn dequantize_batch(qb: &QBatch8, q: &[f32; 64], out: &mut BlockBatch8) {
    for i in 0..64 {
        let qi = q[i];
        for l in 0..LANES {
            out.data[i].0[l] = qb.data[i][l] as f32 * qi;
        }
    }
}

// ---------------------------------------------------------------------------
// Lane-wide transforms
// ---------------------------------------------------------------------------

/// Lane-wide plane rotations of the Loeffler graph — the `[f32; 8]`
/// counterpart of [`Rotors`], one block per lane.
pub trait LaneRotors: Send + Sync {
    fn odd_a8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes);
    fn odd_b8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes);
    fn even8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes);
    fn odd_a_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes);
    fn odd_b_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes);
    fn even_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes);
    /// Quantize a scalar constant to the implementation's arithmetic grid
    /// (identity for exact float) — constants are per-graph, not per-lane.
    fn grid(&self, v: f32) -> f32 {
        v
    }
}

/// Apply a scalar rotator to each lane (bit-identical by construction).
#[inline]
fn lanewise(
    f: impl Fn(f32, f32) -> (f32, f32),
    x: Lanes,
    y: Lanes,
) -> (Lanes, Lanes) {
    let mut ox = [0.0f32; LANES];
    let mut oy = [0.0f32; LANES];
    for l in 0..LANES {
        let (a, b) = f(x.0[l], y.0[l]);
        ox[l] = a;
        oy[l] = b;
    }
    (Lanes(ox), Lanes(oy))
}

impl LaneRotors for ExactRotors {
    #[inline]
    fn odd_a8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        lanewise(|a, b| Rotors::odd_a(self, a, b), x, y)
    }
    #[inline]
    fn odd_b8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        lanewise(|a, b| Rotors::odd_b(self, a, b), x, y)
    }
    #[inline]
    fn even8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        lanewise(|a, b| Rotors::even(self, a, b), x, y)
    }
    #[inline]
    fn odd_a_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        lanewise(|a, b| Rotors::odd_a_inv(self, a, b), x, y)
    }
    #[inline]
    fn odd_b_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        lanewise(|a, b| Rotors::odd_b_inv(self, a, b), x, y)
    }
    #[inline]
    fn even_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        lanewise(|a, b| Rotors::even_inv(self, a, b), x, y)
    }
}

impl LaneRotors for CordicRotors {
    #[inline]
    fn odd_a8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        let (mut a, mut b) = (x.0, y.0);
        self.ra().rotate_cw8(&mut a, &mut b);
        (Lanes(a), Lanes(b))
    }
    #[inline]
    fn odd_b8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        let (mut a, mut b) = (x.0, y.0);
        self.rb().rotate_cw8(&mut a, &mut b);
        (Lanes(a), Lanes(b))
    }
    #[inline]
    fn even8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        let (mut a, mut b) = (x.0, y.0);
        self.re().rotate_cw8(&mut a, &mut b);
        (Lanes(a), Lanes(b))
    }
    #[inline]
    fn odd_a_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        let (mut a, mut b) = (x.0, y.0);
        self.ra().rotate_ccw8(&mut a, &mut b);
        (Lanes(a), Lanes(b))
    }
    #[inline]
    fn odd_b_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        let (mut a, mut b) = (x.0, y.0);
        self.rb().rotate_ccw8(&mut a, &mut b);
        (Lanes(a), Lanes(b))
    }
    #[inline]
    fn even_inv8(&self, x: Lanes, y: Lanes) -> (Lanes, Lanes) {
        let (mut a, mut b) = (x.0, y.0);
        self.re().rotate_ccw8(&mut a, &mut b);
        (Lanes(a), Lanes(b))
    }
    #[inline]
    fn grid(&self, v: f32) -> f32 {
        fxp(v, self.frac_bits())
    }
}

/// Lane-wide forward 8-point DCT-II — a line-for-line mirror of
/// `loeffler::fwd8` with every `f32` widened to
/// [`Lanes`], so each lane runs the exact scalar flow graph.
pub fn fwd8_lanes<R: LaneRotors>(r: &R, x: &[Lanes; 8]) -> [Lanes; 8] {
    // stage 1
    let a0 = x[0] + x[7];
    let a1 = x[1] + x[6];
    let a2 = x[2] + x[5];
    let a3 = x[3] + x[4];
    let a7 = x[0] - x[7];
    let a6 = x[1] - x[6];
    let a5 = x[2] - x[5];
    let a4 = x[3] - x[4];
    // stage 2
    let b0 = a0 + a3;
    let b1 = a1 + a2;
    let b3 = a0 - a3;
    let b2 = a1 - a2;
    let (b4, b7) = r.odd_a8(a4, a7);
    let (b5, b6) = r.odd_b8(a5, a6);
    // stage 3
    let x0 = b0 + b1;
    let x4 = b0 - b1;
    let (x2, x6) = r.even8(b2, b3);
    let c4 = b4 + b6;
    let c6 = b4 - b6;
    let c7 = b7 + b5;
    let c5 = b7 - b5;
    // stage 4
    let x1 = c4 + c7;
    let x7 = c7 - c4;
    let rt2 = r.grid(SQRT2);
    let x3 = c5 * rt2;
    let x5 = c6 * rt2;
    let n = r.grid(INV_SQRT8);
    [
        x0 * n,
        x1 * n,
        x2 * n,
        x3 * n,
        x4 * n,
        x5 * n,
        x6 * n,
        x7 * n,
    ]
}

/// Lane-wide inverse of [`fwd8_lanes`] (mirror of `loeffler::inv8`).
pub fn inv8_lanes<R: LaneRotors>(r: &R, y: &[Lanes; 8]) -> [Lanes; 8] {
    let s8 = r.grid(SQRT8);
    let x0 = y[0] * s8;
    let x1 = y[1] * s8;
    let x2 = y[2] * s8;
    let x3 = y[3] * s8;
    let x4 = y[4] * s8;
    let x5 = y[5] * s8;
    let x6 = y[6] * s8;
    let x7 = y[7] * s8;
    // stage 4 inverse
    let c4 = (x1 - x7) * 0.5;
    let c7 = (x1 + x7) * 0.5;
    let ir2 = r.grid(1.0 / SQRT2);
    let c5 = x3 * ir2;
    let c6 = x5 * ir2;
    // stage 3 odd inverse
    let b4 = (c4 + c6) * 0.5;
    let b6 = (c4 - c6) * 0.5;
    let b7 = (c7 + c5) * 0.5;
    let b5 = (c7 - c5) * 0.5;
    // stage 3 even inverse
    let b0 = (x0 + x4) * 0.5;
    let b1 = (x0 - x4) * 0.5;
    let (b2, b3) = r.even_inv8(x2, x6);
    // stage 2 odd inverse
    let (a4, a7) = r.odd_a_inv8(b4, b7);
    let (a5, a6) = r.odd_b_inv8(b5, b6);
    // stage 2 even inverse
    let a0 = (b0 + b3) * 0.5;
    let a3 = (b0 - b3) * 0.5;
    let a1 = (b1 + b2) * 0.5;
    let a2 = (b1 - b2) * 0.5;
    // stage 1 inverse
    [
        (a0 + a7) * 0.5,
        (a1 + a6) * 0.5,
        (a2 + a5) * 0.5,
        (a3 + a4) * 0.5,
        (a3 - a4) * 0.5,
        (a2 - a5) * 0.5,
        (a1 - a6) * 0.5,
        (a0 - a7) * 0.5,
    ]
}

/// Apply a lane-wide 1-D transform separably over the batch (columns then
/// rows within each lane's 8x8 block — mirror of `loeffler::separable_2d`).
pub fn separable_2d_lanes<R: LaneRotors>(
    r: &R,
    batch: &mut BlockBatch8,
    f: fn(&R, &[Lanes; 8]) -> [Lanes; 8],
) {
    // columns
    for j in 0..8 {
        let col: [Lanes; 8] = std::array::from_fn(|i| batch.data[i * 8 + j]);
        let out = f(r, &col);
        for i in 0..8 {
            batch.data[i * 8 + j] = out[i];
        }
    }
    // rows
    for i in 0..8 {
        let row: [Lanes; 8] = std::array::from_fn(|j| batch.data[i * 8 + j]);
        let out = f(r, &row);
        for j in 0..8 {
            batch.data[i * 8 + j] = out[j];
        }
    }
}

/// Lane-wide separable matrix DCT forward (`B <- D B D^T`), mirroring the
/// scalar `MatrixDct::forward` accumulation order per lane.
pub fn matrix_forward_lanes(d: &[[f32; 8]; 8], batch: &mut BlockBatch8) {
    let mut tmp = [Lanes::ZERO; 64];
    // columns: tmp = D * B
    for k in 0..8 {
        for j in 0..8 {
            let mut acc = Lanes::ZERO;
            for n in 0..8 {
                acc = acc + batch.data[n * 8 + j] * d[k][n];
            }
            tmp[k * 8 + j] = acc;
        }
    }
    // rows: out = tmp * D^T
    for k in 0..8 {
        for l in 0..8 {
            let mut acc = Lanes::ZERO;
            for j in 0..8 {
                acc = acc + tmp[k * 8 + j] * d[l][j];
            }
            batch.data[k * 8 + l] = acc;
        }
    }
}

/// Lane-wide matrix IDCT (`B <- D^T B D`), mirroring the scalar
/// `MatrixDct::inverse` accumulation order per lane.
pub fn matrix_inverse_lanes(d: &[[f32; 8]; 8], batch: &mut BlockBatch8) {
    let mut tmp = [Lanes::ZERO; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = Lanes::ZERO;
            for k in 0..8 {
                acc = acc + batch.data[k * 8 + j] * d[k][i];
            }
            tmp[i * 8 + j] = acc;
        }
    }
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = Lanes::ZERO;
            for l in 0..8 {
                acc = acc + tmp[i * 8 + l] * d[l][j];
            }
            batch.data[i * 8 + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Monomorphized transform dispatch
// ---------------------------------------------------------------------------

/// Statically dispatched transform: the batched replacement for the
/// `Box<dyn Transform8x8>` virtual call. Each arm owns the scalar
/// implementation (used for tail blocks) and drives the matching
/// lane-wide kernel for full batches.
pub enum BatchTransform {
    /// Boxed: the 2x 8x8 f32 matrices would otherwise dominate the enum
    /// size carried by every engine.
    Matrix(Box<MatrixDct>),
    Loeffler(LoefflerDct),
    Cordic(CordicLoefflerDct),
    /// The textbook baseline has no lane kernel; full batches run the
    /// scalar transform once per lane (still bit-identical, never hot).
    Naive(NaiveDct),
}

impl BatchTransform {
    pub fn new(variant: Variant) -> BatchTransform {
        match variant {
            Variant::Dct => {
                BatchTransform::Matrix(Box::new(MatrixDct::new()))
            }
            Variant::Loeffler => {
                BatchTransform::Loeffler(LoefflerDct::new())
            }
            Variant::Cordic => {
                BatchTransform::Cordic(CordicLoefflerDct::default())
            }
            Variant::Naive => BatchTransform::Naive(NaiveDct::new()),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchTransform::Matrix(t) => t.name(),
            BatchTransform::Loeffler(t) => t.name(),
            BatchTransform::Cordic(t) => t.name(),
            BatchTransform::Naive(t) => t.name(),
        }
    }

    /// Scalar forward for tail blocks (static dispatch per arm).
    #[inline]
    pub fn forward_scalar(&self, block: &mut [f32; 64]) {
        match self {
            BatchTransform::Matrix(t) => t.forward(block),
            BatchTransform::Loeffler(t) => t.forward(block),
            BatchTransform::Cordic(t) => t.forward(block),
            BatchTransform::Naive(t) => t.forward(block),
        }
    }

    /// Scalar inverse for tail blocks.
    #[inline]
    pub fn inverse_scalar(&self, block: &mut [f32; 64]) {
        match self {
            BatchTransform::Matrix(t) => t.inverse(block),
            BatchTransform::Loeffler(t) => t.inverse(block),
            BatchTransform::Cordic(t) => t.inverse(block),
            BatchTransform::Naive(t) => t.inverse(block),
        }
    }

    /// Lane-wide forward over a full batch.
    pub fn forward_batch(&self, batch: &mut BlockBatch8) {
        match self {
            BatchTransform::Matrix(t) => {
                matrix_forward_lanes(t.coeffs(), batch)
            }
            BatchTransform::Loeffler(t) => {
                separable_2d_lanes(t.rotors(), batch, fwd8_lanes)
            }
            BatchTransform::Cordic(t) => {
                separable_2d_lanes(t.rotors(), batch, fwd8_lanes)
            }
            BatchTransform::Naive(t) => {
                for l in 0..LANES {
                    let mut blk = batch.extract_lane(l);
                    t.forward(&mut blk);
                    batch.insert_lane(l, &blk);
                }
            }
        }
    }

    /// Lane-wide inverse over a full batch.
    pub fn inverse_batch(&self, batch: &mut BlockBatch8) {
        match self {
            BatchTransform::Matrix(t) => {
                matrix_inverse_lanes(t.coeffs(), batch)
            }
            BatchTransform::Loeffler(t) => {
                separable_2d_lanes(t.rotors(), batch, inv8_lanes)
            }
            BatchTransform::Cordic(t) => {
                separable_2d_lanes(t.rotors(), batch, inv8_lanes)
            }
            BatchTransform::Naive(t) => {
                for l in 0..LANES {
                    let mut blk = batch.extract_lane(l);
                    t.inverse(&mut blk);
                    batch.insert_lane(l, &blk);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Per-call working set of the batch engine (~5 KiB): two lane-major
/// batches, a quantized batch and the scalar-tail buffers. Held in a
/// [`ScratchPool`] so repeated compress/decode calls (and the coordinator
/// worker across jobs) never re-allocate it.
pub struct BlockScratch {
    coef: BlockBatch8,
    recon: BlockBatch8,
    qc: QBatch8,
    block: [f32; 64],
    qblock: [i16; 64],
}

impl BlockScratch {
    pub fn new() -> BlockScratch {
        BlockScratch {
            coef: BlockBatch8::zeroed(),
            recon: BlockBatch8::zeroed(),
            qc: QBatch8::zeroed(),
            block: [0.0; 64],
            qblock: [0; 64],
        }
    }
}

impl Default for BlockScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A small arena of [`BlockScratch`] buffers. Serial callers check out
/// one buffer per image; the parallel lane's band workers each check out
/// their own, so the pool grows to the high-water worker count and is
/// reused for every subsequent call.
#[derive(Default)]
pub struct ScratchPool {
    pool: Mutex<Vec<Box<BlockScratch>>>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Run `f` with a pooled scratch buffer, returning it afterwards.
    pub fn with<T>(&self, f: impl FnOnce(&mut BlockScratch) -> T) -> T {
        let mut s = self
            .pool
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_default();
        let out = f(&mut s);
        self.pool.lock().expect("scratch pool poisoned").push(s);
        out
    }

    /// Buffers currently parked in the pool (for tests).
    pub fn parked(&self) -> usize {
        self.pool.lock().expect("scratch pool poisoned").len()
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// The batched pipeline core shared by both CPU lanes (and, through the
/// stub backend, the GPU lane): walks each block row in batches of
/// [`LANES`] (scalar tail for `grid_width % 8` remainders), quantizing
/// with one table and decoding with the exact matrix IDCT — the same
/// stages, in the same arithmetic order, as the scalar pipelines it
/// replaced.
///
/// # Examples
///
/// Transform + quantize one block row of an 8-aligned image, collecting
/// the planar interchange buffer, the fused zigzag stream, and the
/// reconstruction in a single pass:
///
/// ```
/// use cordic_dct::dct::batch::BatchEngine;
/// use cordic_dct::dct::quant::effective_qtable;
/// use cordic_dct::dct::Variant;
/// use cordic_dct::image::synthetic;
///
/// let img = synthetic::lena_like(32, 8, 1); // 4 blocks, one row
/// let engine = BatchEngine::new(Variant::Cordic, effective_qtable(50));
/// let mut qcoef = vec![0.0f32; 32 * 8];
/// let mut scanned = vec![0i16; 32 * 8];
/// let mut recon = cordic_dct::image::GrayImage::new(32, 8);
/// engine.with_scratch(|s| {
///     engine.forward_quant_row(
///         s, &img, 0, Some(&mut qcoef), 0,
///         Some(&mut scanned), Some((&mut recon, 0)),
///     );
/// });
/// // scan position 0 of block 0 is the quantized DC coefficient
/// assert_eq!(scanned[0] as f32, qcoef[0]);
/// ```
pub struct BatchEngine {
    transform: BatchTransform,
    decoder: MatrixDct,
    qtable: [f32; 64],
    scratch: ScratchPool,
}

impl BatchEngine {
    pub fn new(variant: Variant, qtable: [f32; 64]) -> BatchEngine {
        BatchEngine {
            transform: BatchTransform::new(variant),
            decoder: MatrixDct::new(),
            qtable,
            scratch: ScratchPool::new(),
        }
    }

    pub fn transform_name(&self) -> &'static str {
        self.transform.name()
    }

    pub fn qtable(&self) -> &[f32; 64] {
        &self.qtable
    }

    /// Run `f` with a scratch buffer from this engine's arena.
    pub fn with_scratch<T>(
        &self,
        f: impl FnOnce(&mut BlockScratch) -> T,
    ) -> T {
        self.scratch.with(f)
    }

    /// Forward-transform + quantize one block row: read blocks
    /// `(0.., src_by)` of the 8-aligned `padded` image and, for each
    /// output that is given, write quantized coefficients into block
    /// row `dst_by` of the planar `qcoef` buffer, zigzag-ordered
    /// coefficients into block row `dst_by` of the contiguous `scanned`
    /// buffer (the fused [`quantize_zigzag_batch`] output the entropy
    /// encoder consumes directly), and the decoded pixels into block
    /// row `recon.1` of `recon.0` (dequantize + exact matrix IDCT).
    /// Passing `qcoef: None` skips the planar interchange buffer
    /// entirely (the fused analyze path).
    ///
    /// Quantization runs once per block, fused with the zigzag reorder;
    /// the planar buffer and the reconstruction are derived from the
    /// scan-ordered batch through the inverse scan map, so all outputs
    /// stay bit-identical to the historical quantize-then-scatter path.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_quant_row(
        &self,
        s: &mut BlockScratch,
        padded: &GrayImage,
        src_by: usize,
        mut qcoef: Option<&mut [f32]>,
        dst_by: usize,
        mut scanned: Option<&mut [i16]>,
        mut recon: Option<(&mut GrayImage, usize)>,
    ) {
        let w = padded.width;
        debug_assert!(w % BLOCK == 0);
        let gw = w / BLOCK;
        let mut bx = 0;
        while bx + LANES <= gw {
            gather(&mut s.coef, padded, bx, src_by, LANES);
            self.transform.forward_batch(&mut s.coef);
            quantize_zigzag_batch(&s.coef, &self.qtable, &mut s.qc);
            if let Some(out) = qcoef.as_mut() {
                scatter_coef_scan(&s.qc, out, w, bx, dst_by, LANES);
            }
            if let Some(out) = scanned.as_mut() {
                scatter_scan(&s.qc, out, gw, bx, dst_by, LANES);
            }
            if let Some((img, rby)) = recon.as_mut() {
                dequantize_scan_batch(&s.qc, &self.qtable, &mut s.recon);
                matrix_inverse_lanes(self.decoder.coeffs(), &mut s.recon);
                scatter_blocks(&s.recon, img, bx, *rby, LANES);
            }
            bx += LANES;
        }
        // scalar tail: the exact seed-path per-block sequence
        while bx < gw {
            extract_block(padded, bx, src_by, &mut s.block);
            self.transform.forward_scalar(&mut s.block);
            quantize_block(&s.block, &self.qtable, &mut s.qblock);
            if let Some(out) = qcoef.as_mut() {
                store_coef_planar(out, w, bx, dst_by, &s.qblock);
            }
            if let Some(out) = scanned.as_mut() {
                let base = (dst_by * gw + bx) * 64;
                out[base..base + 64]
                    .copy_from_slice(&zigzag_scan(&s.qblock));
            }
            if let Some((img, rby)) = recon.as_mut() {
                dequantize_block(&s.qblock, &self.qtable, &mut s.block);
                self.decoder.inverse(&mut s.block);
                store_block(img, bx, *rby, &s.block);
            }
            bx += 1;
        }
    }

    /// Decode one block row of a planar coefficient buffer (dequantize +
    /// exact matrix IDCT) into block row `dst_by` of `img`.
    pub fn decode_row(
        &self,
        s: &mut BlockScratch,
        qcoef: &[f32],
        width: usize,
        src_by: usize,
        img: &mut GrayImage,
        dst_by: usize,
    ) {
        debug_assert!(width % BLOCK == 0);
        let gw = width / BLOCK;
        let mut bx = 0;
        while bx + LANES <= gw {
            gather_coef(qcoef, width, bx, src_by, LANES, &mut s.qc);
            dequantize_batch(&s.qc, &self.qtable, &mut s.recon);
            matrix_inverse_lanes(self.decoder.coeffs(), &mut s.recon);
            scatter_blocks(&s.recon, img, bx, dst_by, LANES);
            bx += LANES;
        }
        while bx < gw {
            load_coef_planar(qcoef, width, bx, src_by, &mut s.qblock);
            dequantize_block(&s.qblock, &self.qtable, &mut s.block);
            self.decoder.inverse(&mut s.block);
            store_block(img, bx, dst_by, &s.block);
            bx += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::zigzag;
    use crate::dct::quant::effective_qtable;
    use crate::image::synthetic;
    use crate::util::prng::Rng;

    fn rand_batch(seed: u64) -> BlockBatch8 {
        let mut rng = Rng::new(seed);
        let mut b = BlockBatch8::zeroed();
        for e in b.data.iter_mut() {
            for v in e.0.iter_mut() {
                *v = rng.range_f64(-128.0, 128.0) as f32;
            }
        }
        b
    }

    #[test]
    fn lane_extract_insert_roundtrip() {
        let b = rand_batch(1);
        let mut c = BlockBatch8::zeroed();
        for l in 0..LANES {
            let blk = b.extract_lane(l);
            c.insert_lane(l, &blk);
        }
        assert_eq!(b, c);
    }

    #[test]
    fn forward_batch_matches_scalar_per_lane() {
        for variant in [
            Variant::Dct,
            Variant::Loeffler,
            Variant::Cordic,
            Variant::Naive,
        ] {
            let bt = BatchTransform::new(variant);
            let scalar = variant.transform();
            let mut batch = rand_batch(7);
            let blocks: Vec<[f32; 64]> =
                (0..LANES).map(|l| batch.extract_lane(l)).collect();
            bt.forward_batch(&mut batch);
            for (l, blk) in blocks.iter().enumerate() {
                let mut want = *blk;
                scalar.forward(&mut want);
                let got = batch.extract_lane(l);
                assert_eq!(
                    got[..],
                    want[..],
                    "{} lane {l} diverged",
                    bt.name()
                );
            }
        }
    }

    #[test]
    fn inverse_batch_matches_scalar_per_lane() {
        for variant in [
            Variant::Dct,
            Variant::Loeffler,
            Variant::Cordic,
            Variant::Naive,
        ] {
            let bt = BatchTransform::new(variant);
            let scalar = variant.transform();
            let mut batch = rand_batch(11);
            let blocks: Vec<[f32; 64]> =
                (0..LANES).map(|l| batch.extract_lane(l)).collect();
            bt.inverse_batch(&mut batch);
            for (l, blk) in blocks.iter().enumerate() {
                let mut want = *blk;
                scalar.inverse(&mut want);
                let got = batch.extract_lane(l);
                assert_eq!(got[..], want[..], "{} lane {l}", bt.name());
            }
        }
    }

    #[test]
    fn quantize_batch_matches_scalar() {
        let q = effective_qtable(35);
        let batch = rand_batch(3);
        let mut qb = QBatch8::zeroed();
        quantize_batch(&batch, &q, &mut qb);
        let mut deq = BlockBatch8::zeroed();
        dequantize_batch(&qb, &q, &mut deq);
        for l in 0..LANES {
            let blk = batch.extract_lane(l);
            let mut want = [0i16; 64];
            quantize_block(&blk, &q, &mut want);
            for i in 0..64 {
                assert_eq!(qb.data[i][l], want[i], "lane {l} coef {i}");
            }
            let mut wantd = [0.0f32; 64];
            dequantize_block(&want, &q, &mut wantd);
            assert_eq!(deq.extract_lane(l)[..], wantd[..]);
        }
    }

    #[test]
    fn fused_zigzag_matches_quantize_then_scan() {
        let q = effective_qtable(50);
        let batch = rand_batch(4);
        let mut fused = QBatch8::zeroed();
        quantize_zigzag_batch(&batch, &q, &mut fused);
        for l in 0..LANES {
            let blk = batch.extract_lane(l);
            let mut qc = [0i16; 64];
            quantize_block(&blk, &q, &mut qc);
            let z = zigzag::scan(&qc);
            for k in 0..64 {
                assert_eq!(fused.data[k][l], z[k], "lane {l} scan {k}");
            }
        }
    }

    #[test]
    fn scan_order_scatters_match_row_major() {
        let q = effective_qtable(50);
        let batch = rand_batch(21);
        let mut qb_row = QBatch8::zeroed();
        let mut qb_scan = QBatch8::zeroed();
        quantize_batch(&batch, &q, &mut qb_row);
        quantize_zigzag_batch(&batch, &q, &mut qb_scan);
        // planar scatter from the scan-ordered batch == row-major scatter
        let mut via_row = vec![0.0f32; 64 * 8];
        let mut via_scan = vec![0.0f32; 64 * 8];
        scatter_coef(&qb_row, &mut via_row, 64, 0, 0, LANES);
        scatter_coef_scan(&qb_scan, &mut via_scan, 64, 0, 0, LANES);
        assert_eq!(via_row, via_scan);
        // dequantize from scan order == dequantize from row-major
        let mut deq_row = BlockBatch8::zeroed();
        let mut deq_scan = BlockBatch8::zeroed();
        dequantize_batch(&qb_row, &q, &mut deq_row);
        dequantize_scan_batch(&qb_scan, &q, &mut deq_scan);
        assert_eq!(deq_row, deq_scan);
        // the contiguous scan buffer carries each lane's zigzag sequence
        let mut scanned = vec![0i16; 64 * LANES];
        scatter_scan(&qb_scan, &mut scanned, LANES, 0, 0, LANES);
        for l in 0..LANES {
            for k in 0..64 {
                assert_eq!(scanned[l * 64 + k], qb_scan.data[k][l]);
            }
        }
    }

    #[test]
    fn gather_matches_extract_block_and_zeroes_tail() {
        let img = synthetic::lena_like(48, 16, 5);
        let mut batch = rand_batch(9); // dirty start: gather must overwrite
        gather(&mut batch, &img, 0, 1, 3);
        let mut want = [0.0f32; 64];
        for l in 0..3 {
            extract_block(&img, l, 1, &mut want);
            assert_eq!(batch.extract_lane(l)[..], want[..]);
        }
        for l in 3..LANES {
            assert!(batch.extract_lane(l).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn scatter_blocks_matches_store_block() {
        let img = synthetic::lena_like(64, 8, 6);
        let mut batch = BlockBatch8::zeroed();
        gather(&mut batch, &img, 0, 0, LANES);
        let mut via_batch = GrayImage::new(64, 8);
        scatter_blocks(&batch, &mut via_batch, 0, 0, LANES);
        let mut via_scalar = GrayImage::new(64, 8);
        let mut blk = [0.0f32; 64];
        for bx in 0..LANES {
            extract_block(&img, bx, 0, &mut blk);
            store_block(&mut via_scalar, bx, 0, &blk);
        }
        assert_eq!(via_batch, via_scalar);
        assert_eq!(via_batch, img);
    }

    #[test]
    fn coef_gather_scatter_roundtrip_with_tail() {
        let width = 40; // 5 blocks: one tail-sized batch
        let mut rng = Rng::new(12);
        let mut qb = QBatch8::zeroed();
        for e in qb.data.iter_mut() {
            for v in e.iter_mut().take(5) {
                *v = rng.range_i64(-512, 512) as i16;
            }
        }
        let mut buf = vec![0.0f32; width * 8];
        scatter_coef(&qb, &mut buf, width, 0, 0, 5);
        let mut back = QBatch8::zeroed();
        gather_coef(&buf, width, 0, 0, 5, &mut back);
        assert_eq!(qb, back);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let pool = ScratchPool::new();
        pool.with(|s| s.block[0] = 1.0);
        assert_eq!(pool.parked(), 1);
        pool.with(|s| assert_eq!(s.block[0], 1.0));
        assert_eq!(pool.parked(), 1);
    }

    #[test]
    fn engine_row_matches_seed_scalar_sequence() {
        let img = synthetic::cablecar_like(72, 8, 8); // 9 blocks: tail of 1
        let q = effective_qtable(50);
        let engine = BatchEngine::new(Variant::Cordic, q);
        let mut qcoef = vec![0.0f32; 72 * 8];
        let mut scanned = vec![0i16; 72 * 8];
        let mut recon = GrayImage::new(72, 8);
        engine.with_scratch(|s| {
            engine.forward_quant_row(
                s,
                &img,
                0,
                Some(&mut qcoef),
                0,
                Some(&mut scanned),
                Some((&mut recon, 0)),
            );
        });
        // seed-path reference
        let t = Variant::Cordic.transform();
        let dec = MatrixDct::new();
        let mut want_q = vec![0.0f32; 72 * 8];
        let mut want_s = vec![0i16; 72 * 8];
        let mut want_r = GrayImage::new(72, 8);
        let mut blk = [0.0f32; 64];
        let mut qc = [0i16; 64];
        for bx in 0..9 {
            extract_block(&img, bx, 0, &mut blk);
            t.forward(&mut blk);
            quantize_block(&blk, &q, &mut qc);
            store_coef_planar(&mut want_q, 72, bx, 0, &qc);
            want_s[bx * 64..(bx + 1) * 64]
                .copy_from_slice(&zigzag::scan(&qc));
            dequantize_block(&qc, &q, &mut blk);
            dec.inverse(&mut blk);
            store_block(&mut want_r, bx, 0, &blk);
        }
        assert_eq!(qcoef, want_q);
        assert_eq!(scanned, want_s);
        assert_eq!(recon, want_r);
        // decode side reproduces the same reconstruction
        let mut decoded = GrayImage::new(72, 8);
        engine.with_scratch(|s| {
            engine.decode_row(s, &qcoef, 72, 0, &mut decoded, 0);
        });
        assert_eq!(decoded, want_r);
    }
}
