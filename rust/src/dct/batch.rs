//! Batched SIMD-style block engine — the CPU lanes' answer to the
//! GPU's thread-per-block mapping, width-generic over the lane count.
//!
//! The scalar pipelines walk the block grid one 8x8 block at a time
//! through a `Box<dyn Transform8x8>` virtual call, which stops the
//! autovectorizer at the hottest loop in the crate. This module
//! restructures the loop into a *lane-major structure-of-arrays* batch:
//! `W` neighbouring blocks ride together, one block per SIMD lane, and
//! every transform step is expressed as an `[f32; W]`-element operation
//! the compiler can map directly onto vector instructions.
//!
//! ```text
//!            scalar layout (AoS)              lane-major SoA (BlockBatch<W>)
//!   block 0: [e0 e1 e2 ... e63]        data[0]  = [e0 of blocks 0..W]
//!   block 1: [e0 e1 e2 ... e63]   ==>  data[1]  = [e1 of blocks 0..W]
//!   ...                                ...
//!   block 7: [e0 e1 e2 ... e63]        data[63] = [e63 of blocks 0..W]
//! ```
//!
//! (This layout diagram is promoted into `ARCHITECTURE.md` — keep the
//! two copies in sync.)
//!
//! `data[i]` holds element `i` (row-major position within the 8x8 block)
//! of all `W` blocks, so one [`LanesN`] add/mul advances the same
//! flow-graph edge of `W` independent blocks at once.
//!
//! **Width dispatch.** The engine is compiled at two widths — 8 (one
//! AVX2 ymm register of f32 per batch element) and 16 (one AVX-512 zmm
//! register) — and picks one per [`BatchEngine`] from [`BatchWidth`]:
//! an explicit `W8`/`W16` config, the `CORDIC_DCT_BATCH_WIDTH` env
//! override, or `Auto` runtime detection (16 when `avx512f` is
//! detected on x86-64, the portable 8-wide path everywhere else). Both
//! widths run plain elementwise Rust, so non-AVX-512 hosts and CI can
//! run the 16-wide path too — just on narrower registers.
//!
//! **Bit-exactness.** Every lane performs *exactly* the scalar op
//! sequence of the serial pipeline — same IEEE f32 adds/muls/divides in
//! the same order, per block — because (a) the Loeffler/matrix lane code
//! is a line-for-line mirror of the scalar flow graph with each `f32`
//! widened to [`LanesN`], (b) the exact rotators delegate per lane to the
//! scalar [`Rotors`] methods, (c) the CORDIC rotators run the same
//! fixed-point grid (`fxp`) per lane, and (d) the integer fixed-point
//! lane's scalar path *is* the `W = 1` instantiation of its lane kernel.
//! Elementwise arithmetic is deterministic and width-invariant, so
//! `qcoef` and the reconstruction are bit-identical across scalar,
//! 8-wide and 16-wide paths (locked by `tests/batch_parity.rs`).
//!
//! [`BatchEngine`] is the monomorphized pipeline core both
//! [`CpuPipeline`](super::pipeline::CpuPipeline) and
//! [`ParallelCpuPipeline`](super::parallel::ParallelCpuPipeline) (and
//! through them the per-plane color pipeline) run on: it walks each block
//! row in batches of its resolved width, falls back to the scalar path
//! for the `grid_width % W` tail, and reuses [`BlockScratch`] buffers
//! from a per-pipeline [`ScratchPool`] arena instead of allocating per
//! call.

use std::sync::Mutex;

use crate::codec::zigzag::{scan as zigzag_scan, INV_ZIGZAG, ZIGZAG};
use crate::image::GrayImage;

use super::blocks::{
    extract_block, load_coef_planar, store_block, store_coef_planar, BLOCK,
    LEVEL_SHIFT,
};
use super::cordic::fxp;
use super::cordic_fxp::{CordicFxpDct, FxpPrecision};
use super::cordic_loeffler::{CordicLoefflerDct, CordicRotors};
use super::loeffler::{
    ExactRotors, LoefflerDct, Rotors, INV_SQRT8, SQRT2, SQRT8,
};
use super::matrix::MatrixDct;
use super::naive::NaiveDct;
use super::quant::{dequantize_block, quantize_block};
use super::{Transform8x8, Variant};

/// Default number of blocks per batch — one block per AVX2-class SIMD
/// lane. The engine also compiles a 16-wide instantiation; see
/// [`BatchWidth`].
pub const LANES: usize = 8;

/// The wide lane count (AVX-512-class: one zmm register of f32).
pub const LANES_WIDE: usize = 16;

/// Env override consulted by [`BatchWidth::Auto`]: set to `8` or `16`
/// to force a lane width per process.
pub const BATCH_WIDTH_ENV: &str = "CORDIC_DCT_BATCH_WIDTH";

/// Per-engine lane-width selection, resolved once at engine build.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BatchWidth {
    /// `CORDIC_DCT_BATCH_WIDTH` env override if set, else hardware
    /// detection ([`detected_width`]).
    #[default]
    Auto,
    /// Force the 8-wide engine.
    W8,
    /// Force the 16-wide engine.
    W16,
}

impl BatchWidth {
    /// Parse a CLI/config string (`auto`, `8`, `16`).
    pub fn parse(s: &str) -> Option<BatchWidth> {
        match s {
            "auto" => Some(BatchWidth::Auto),
            "8" | "w8" => Some(BatchWidth::W8),
            "16" | "w16" => Some(BatchWidth::W16),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BatchWidth::Auto => "auto",
            BatchWidth::W8 => "8",
            BatchWidth::W16 => "16",
        }
    }

    /// Resolve to a concrete lane count (8 or 16).
    pub fn resolve(self) -> usize {
        match self {
            BatchWidth::W8 => LANES,
            BatchWidth::W16 => LANES_WIDE,
            BatchWidth::Auto => {
                match std::env::var(BATCH_WIDTH_ENV).ok().as_deref() {
                    Some("16") => LANES_WIDE,
                    Some("8") => LANES,
                    _ => detected_width(),
                }
            }
        }
    }
}

/// Hardware-detected default lane width: 16 on AVX-512-class x86-64
/// (one f32 batch element per zmm register), 8 everywhere else — the
/// portable fallback, so non-AVX-512 hosts and CI runners take the
/// 8-wide path by default.
pub fn detected_width() -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return LANES_WIDE;
        }
    }
    LANES
}

/// Engine-level configuration threaded from `ServiceConfig`/CLI down
/// through both CPU pipelines into [`BatchEngine`]: the lane width and
/// the fixed-point lane's precision. `Default` is the historical
/// behaviour (auto width, calibrated fxp precision).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct EngineConfig {
    pub width: BatchWidth,
    /// Precision of the `Variant::CordicFxp` transform; ignored by the
    /// f32 variants.
    pub precision: FxpPrecision,
}

/// A `W`-wide lane vector: one `f32` per block in the batch. All
/// arithmetic is elementwise, so lane `l` sees exactly the scalar op
/// sequence of block `l`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LanesN<const W: usize>(pub [f32; W]);

/// The historical 8-wide lane vector.
pub type Lanes = LanesN<LANES>;

impl<const W: usize> LanesN<W> {
    pub const ZERO: LanesN<W> = LanesN([0.0; W]);

    /// Broadcast a scalar constant to all lanes.
    #[inline]
    pub fn splat(v: f32) -> LanesN<W> {
        LanesN([v; W])
    }
}

impl<const W: usize> std::ops::Add for LanesN<W> {
    type Output = LanesN<W>;
    #[inline]
    fn add(self, rhs: LanesN<W>) -> LanesN<W> {
        let mut out = [0.0f32; W];
        for l in 0..W {
            out[l] = self.0[l] + rhs.0[l];
        }
        LanesN(out)
    }
}

impl<const W: usize> std::ops::Sub for LanesN<W> {
    type Output = LanesN<W>;
    #[inline]
    fn sub(self, rhs: LanesN<W>) -> LanesN<W> {
        let mut out = [0.0f32; W];
        for l in 0..W {
            out[l] = self.0[l] - rhs.0[l];
        }
        LanesN(out)
    }
}

/// Scale every lane by the same scalar (mirrors `x * c` in scalar code —
/// the only multiply shape the lane kernels need; elementwise
/// `LanesN * LanesN` is deliberately absent until a kernel requires it).
impl<const W: usize> std::ops::Mul<f32> for LanesN<W> {
    type Output = LanesN<W>;
    #[inline]
    fn mul(self, rhs: f32) -> LanesN<W> {
        let mut out = [0.0f32; W];
        for l in 0..W {
            out[l] = self.0[l] * rhs;
        }
        LanesN(out)
    }
}

/// Lane-major SoA batch: element `i` of all `W` blocks lives in
/// `data[i]` (see the module-level layout diagram).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockBatch<const W: usize> {
    pub data: [LanesN<W>; 64],
}

/// The historical 8-wide batch.
pub type BlockBatch8 = BlockBatch<LANES>;
/// The AVX-512-class 16-wide batch.
pub type BlockBatch16 = BlockBatch<LANES_WIDE>;

impl<const W: usize> BlockBatch<W> {
    pub fn zeroed() -> BlockBatch<W> {
        BlockBatch {
            data: [LanesN::ZERO; 64],
        }
    }

    /// Copy lane `l` out as a scalar row-major block.
    #[inline]
    pub fn extract_lane(&self, l: usize) -> [f32; 64] {
        std::array::from_fn(|i| self.data[i].0[l])
    }

    /// Overwrite lane `l` from a scalar row-major block.
    #[inline]
    pub fn insert_lane(&mut self, l: usize, block: &[f32; 64]) {
        for i in 0..64 {
            self.data[i].0[l] = block[i];
        }
    }
}

impl<const W: usize> Default for BlockBatch<W> {
    fn default() -> Self {
        Self::zeroed()
    }
}

/// Quantized-coefficient batch in the same lane-major layout
/// (`data[i][l]` = coefficient `i` of block `l`).
#[derive(Clone, Debug, PartialEq)]
pub struct QBatch<const W: usize> {
    pub data: [[i16; W]; 64],
}

/// The historical 8-wide quantized batch.
pub type QBatch8 = QBatch<LANES>;
/// The 16-wide quantized batch.
pub type QBatch16 = QBatch<LANES_WIDE>;

impl<const W: usize> QBatch<W> {
    pub fn zeroed() -> QBatch<W> {
        QBatch {
            data: [[0i16; W]; 64],
        }
    }
}

impl<const W: usize> Default for QBatch<W> {
    fn default() -> Self {
        Self::zeroed()
    }
}

// ---------------------------------------------------------------------------
// Gather / scatter between planar images and the lane-major batch
// ---------------------------------------------------------------------------

/// Gather blocks `(bx0..bx0+n, by)` of an 8-aligned image into the batch,
/// applying the -128 level shift (lane `l` = block `bx0 + l`). Inactive
/// lanes (`l >= n`) are zeroed so tail batches stay deterministic.
pub fn gather<const W: usize>(
    batch: &mut BlockBatch<W>,
    img: &GrayImage,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=W).contains(&n));
    let w = img.width;
    for l in 0..n {
        for r in 0..BLOCK {
            let src = (by * BLOCK + r) * w + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                batch.data[r * BLOCK + c].0[l] =
                    img.data[src + c] as f32 - LEVEL_SHIFT;
            }
        }
    }
    for e in batch.data.iter_mut() {
        for v in e.0.iter_mut().skip(n) {
            *v = 0.0;
        }
    }
}

/// Scatter the first `n` lanes back into the image as reconstructed
/// pixels (un-shift, clamp, round — the exact scalar `store_block` math).
pub fn scatter_blocks<const W: usize>(
    batch: &BlockBatch<W>,
    img: &mut GrayImage,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=W).contains(&n));
    let w = img.width;
    for l in 0..n {
        for r in 0..BLOCK {
            let dst = (by * BLOCK + r) * w + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                img.data[dst + c] = (batch.data[r * BLOCK + c].0[l]
                    + LEVEL_SHIFT)
                    .clamp(0.0, 255.0)
                    .round() as u8;
            }
        }
    }
}

/// Scatter the first `n` quantized lanes into a planar f32 coefficient
/// buffer (the PJRT interchange layout), blocks `(bx0..bx0+n, by)`.
pub fn scatter_coef<const W: usize>(
    qb: &QBatch<W>,
    buf: &mut [f32],
    width: usize,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=W).contains(&n));
    for l in 0..n {
        for r in 0..BLOCK {
            let dst = (by * BLOCK + r) * width + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                buf[dst + c] = qb.data[r * BLOCK + c][l] as f32;
            }
        }
    }
}

/// Scatter the first `n` lanes of a *scan-ordered* quantized batch (the
/// [`quantize_zigzag_batch`] output) into a planar f32 coefficient
/// buffer. Same values as [`scatter_coef`] over the row-major batch —
/// only the source indexing differs.
pub fn scatter_coef_scan<const W: usize>(
    qb: &QBatch<W>,
    buf: &mut [f32],
    width: usize,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=W).contains(&n));
    for l in 0..n {
        for r in 0..BLOCK {
            let dst = (by * BLOCK + r) * width + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                buf[dst + c] =
                    qb.data[INV_ZIGZAG[r * BLOCK + c]][l] as f32;
            }
        }
    }
}

/// Scatter the first `n` lanes of a scan-ordered quantized batch into a
/// contiguous entropy-coding buffer: block `(bx0 + l, by)` lands at
/// `((by * grid_w + bx0 + l) * 64)..+64`, already in zigzag order — the
/// layout [`crate::codec::encoder::ScanCoefs`] carries straight into the
/// entropy encoder.
pub fn scatter_scan<const W: usize>(
    qb: &QBatch<W>,
    scanned: &mut [i16],
    grid_w: usize,
    bx0: usize,
    by: usize,
    n: usize,
) {
    debug_assert!((1..=W).contains(&n));
    for l in 0..n {
        let base = (by * grid_w + bx0 + l) * 64;
        for k in 0..64 {
            scanned[base + k] = qb.data[k][l];
        }
    }
}

/// Lane-wide dequantize of a *scan-ordered* quantized batch back to a
/// row-major coefficient batch — the exact scalar [`dequantize_block`]
/// multiplies (elementwise, so storage order cannot change the values).
pub fn dequantize_scan_batch<const W: usize>(
    qb: &QBatch<W>,
    q: &[f32; 64],
    out: &mut BlockBatch<W>,
) {
    for (k, &i) in ZIGZAG.iter().enumerate() {
        let qi = q[i];
        for l in 0..W {
            out.data[i].0[l] = qb.data[k][l] as f32 * qi;
        }
    }
}

/// Gather `n` blocks of a planar f32 coefficient buffer into the
/// quantized batch (inverse of [`scatter_coef`]); inactive lanes zeroed.
pub fn gather_coef<const W: usize>(
    buf: &[f32],
    width: usize,
    bx0: usize,
    by: usize,
    n: usize,
    qb: &mut QBatch<W>,
) {
    debug_assert!((1..=W).contains(&n));
    for l in 0..n {
        for r in 0..BLOCK {
            let src = (by * BLOCK + r) * width + (bx0 + l) * BLOCK;
            for c in 0..BLOCK {
                qb.data[r * BLOCK + c][l] =
                    buf[src + c].round_ties_even() as i16;
            }
        }
    }
    for e in qb.data.iter_mut() {
        for v in e.iter_mut().skip(n) {
            *v = 0;
        }
    }
}

// ---------------------------------------------------------------------------
// Lane-wide quantization
// ---------------------------------------------------------------------------

/// Lane-wide quantize: `round_half_even(coef / q)` per lane — the exact
/// scalar [`quantize_block`] math, `W` blocks at a time.
pub fn quantize_batch<const W: usize>(
    batch: &BlockBatch<W>,
    q: &[f32; 64],
    out: &mut QBatch<W>,
) {
    for i in 0..64 {
        let qi = q[i];
        let lanes = &batch.data[i].0;
        for l in 0..W {
            out.data[i][l] = (lanes[l] / qi).round_ties_even() as i16;
        }
    }
}

/// Fused quantize→zigzag: quantize the batch and emit each lane's
/// coefficients already in zigzag scan order (`out.data[k][l]` is scan
/// position `k` of block `l`) — the symbolization front half without the
/// intermediate row-major store. Values are bit-identical to
/// `quantize_block` followed by `zigzag::scan` per block.
pub fn quantize_zigzag_batch<const W: usize>(
    batch: &BlockBatch<W>,
    q: &[f32; 64],
    out: &mut QBatch<W>,
) {
    for (k, &i) in ZIGZAG.iter().enumerate() {
        let qi = q[i];
        let lanes = &batch.data[i].0;
        for l in 0..W {
            out.data[k][l] = (lanes[l] / qi).round_ties_even() as i16;
        }
    }
}

/// Lane-wide dequantize back to coefficient space (exact scalar
/// [`dequantize_block`] math).
pub fn dequantize_batch<const W: usize>(
    qb: &QBatch<W>,
    q: &[f32; 64],
    out: &mut BlockBatch<W>,
) {
    for i in 0..64 {
        let qi = q[i];
        for l in 0..W {
            out.data[i].0[l] = qb.data[i][l] as f32 * qi;
        }
    }
}

// ---------------------------------------------------------------------------
// Lane-wide transforms
// ---------------------------------------------------------------------------

/// Lane-wide plane rotations of the Loeffler graph — the `[f32; W]`
/// counterpart of [`Rotors`], one block per lane. (Method names keep
/// their historical `8` suffix from the fixed-width engine; they are
/// width-generic.)
pub trait LaneRotors<const W: usize>: Send + Sync {
    fn odd_a8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>);
    fn odd_b8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>);
    fn even8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>);
    fn odd_a_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>);
    fn odd_b_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>);
    fn even_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>);
    /// Quantize a scalar constant to the implementation's arithmetic grid
    /// (identity for exact float) — constants are per-graph, not per-lane.
    fn grid(&self, v: f32) -> f32 {
        v
    }
}

/// Apply a scalar rotator to each lane (bit-identical by construction).
#[inline]
fn lanewise<const W: usize>(
    f: impl Fn(f32, f32) -> (f32, f32),
    x: LanesN<W>,
    y: LanesN<W>,
) -> (LanesN<W>, LanesN<W>) {
    let mut ox = [0.0f32; W];
    let mut oy = [0.0f32; W];
    for l in 0..W {
        let (a, b) = f(x.0[l], y.0[l]);
        ox[l] = a;
        oy[l] = b;
    }
    (LanesN(ox), LanesN(oy))
}

impl<const W: usize> LaneRotors<W> for ExactRotors {
    #[inline]
    fn odd_a8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>) {
        lanewise(|a, b| Rotors::odd_a(self, a, b), x, y)
    }
    #[inline]
    fn odd_b8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>) {
        lanewise(|a, b| Rotors::odd_b(self, a, b), x, y)
    }
    #[inline]
    fn even8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>) {
        lanewise(|a, b| Rotors::even(self, a, b), x, y)
    }
    #[inline]
    fn odd_a_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>) {
        lanewise(|a, b| Rotors::odd_a_inv(self, a, b), x, y)
    }
    #[inline]
    fn odd_b_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>) {
        lanewise(|a, b| Rotors::odd_b_inv(self, a, b), x, y)
    }
    #[inline]
    fn even_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>) {
        lanewise(|a, b| Rotors::even_inv(self, a, b), x, y)
    }
}

impl<const W: usize> LaneRotors<W> for CordicRotors {
    #[inline]
    fn odd_a8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>) {
        let (mut a, mut b) = (x.0, y.0);
        self.ra().rotate_cw_lanes(&mut a, &mut b);
        (LanesN(a), LanesN(b))
    }
    #[inline]
    fn odd_b8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>) {
        let (mut a, mut b) = (x.0, y.0);
        self.rb().rotate_cw_lanes(&mut a, &mut b);
        (LanesN(a), LanesN(b))
    }
    #[inline]
    fn even8(&self, x: LanesN<W>, y: LanesN<W>) -> (LanesN<W>, LanesN<W>) {
        let (mut a, mut b) = (x.0, y.0);
        self.re().rotate_cw_lanes(&mut a, &mut b);
        (LanesN(a), LanesN(b))
    }
    #[inline]
    fn odd_a_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>) {
        let (mut a, mut b) = (x.0, y.0);
        self.ra().rotate_ccw_lanes(&mut a, &mut b);
        (LanesN(a), LanesN(b))
    }
    #[inline]
    fn odd_b_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>) {
        let (mut a, mut b) = (x.0, y.0);
        self.rb().rotate_ccw_lanes(&mut a, &mut b);
        (LanesN(a), LanesN(b))
    }
    #[inline]
    fn even_inv8(
        &self,
        x: LanesN<W>,
        y: LanesN<W>,
    ) -> (LanesN<W>, LanesN<W>) {
        let (mut a, mut b) = (x.0, y.0);
        self.re().rotate_ccw_lanes(&mut a, &mut b);
        (LanesN(a), LanesN(b))
    }
    #[inline]
    fn grid(&self, v: f32) -> f32 {
        fxp(v, self.frac_bits())
    }
}

/// Lane-wide forward 8-point DCT-II — a line-for-line mirror of
/// `loeffler::fwd8` with every `f32` widened to
/// [`LanesN`], so each lane runs the exact scalar flow graph.
pub fn fwd8_lanes<const W: usize, R: LaneRotors<W>>(
    r: &R,
    x: &[LanesN<W>; 8],
) -> [LanesN<W>; 8] {
    // stage 1
    let a0 = x[0] + x[7];
    let a1 = x[1] + x[6];
    let a2 = x[2] + x[5];
    let a3 = x[3] + x[4];
    let a7 = x[0] - x[7];
    let a6 = x[1] - x[6];
    let a5 = x[2] - x[5];
    let a4 = x[3] - x[4];
    // stage 2
    let b0 = a0 + a3;
    let b1 = a1 + a2;
    let b3 = a0 - a3;
    let b2 = a1 - a2;
    let (b4, b7) = r.odd_a8(a4, a7);
    let (b5, b6) = r.odd_b8(a5, a6);
    // stage 3
    let x0 = b0 + b1;
    let x4 = b0 - b1;
    let (x2, x6) = r.even8(b2, b3);
    let c4 = b4 + b6;
    let c6 = b4 - b6;
    let c7 = b7 + b5;
    let c5 = b7 - b5;
    // stage 4
    let x1 = c4 + c7;
    let x7 = c7 - c4;
    let rt2 = r.grid(SQRT2);
    let x3 = c5 * rt2;
    let x5 = c6 * rt2;
    let n = r.grid(INV_SQRT8);
    [
        x0 * n,
        x1 * n,
        x2 * n,
        x3 * n,
        x4 * n,
        x5 * n,
        x6 * n,
        x7 * n,
    ]
}

/// Lane-wide inverse of [`fwd8_lanes`] (mirror of `loeffler::inv8`).
pub fn inv8_lanes<const W: usize, R: LaneRotors<W>>(
    r: &R,
    y: &[LanesN<W>; 8],
) -> [LanesN<W>; 8] {
    let s8 = r.grid(SQRT8);
    let x0 = y[0] * s8;
    let x1 = y[1] * s8;
    let x2 = y[2] * s8;
    let x3 = y[3] * s8;
    let x4 = y[4] * s8;
    let x5 = y[5] * s8;
    let x6 = y[6] * s8;
    let x7 = y[7] * s8;
    // stage 4 inverse
    let c4 = (x1 - x7) * 0.5;
    let c7 = (x1 + x7) * 0.5;
    let ir2 = r.grid(1.0 / SQRT2);
    let c5 = x3 * ir2;
    let c6 = x5 * ir2;
    // stage 3 odd inverse
    let b4 = (c4 + c6) * 0.5;
    let b6 = (c4 - c6) * 0.5;
    let b7 = (c7 + c5) * 0.5;
    let b5 = (c7 - c5) * 0.5;
    // stage 3 even inverse
    let b0 = (x0 + x4) * 0.5;
    let b1 = (x0 - x4) * 0.5;
    let (b2, b3) = r.even_inv8(x2, x6);
    // stage 2 odd inverse
    let (a4, a7) = r.odd_a_inv8(b4, b7);
    let (a5, a6) = r.odd_b_inv8(b5, b6);
    // stage 2 even inverse
    let a0 = (b0 + b3) * 0.5;
    let a3 = (b0 - b3) * 0.5;
    let a1 = (b1 + b2) * 0.5;
    let a2 = (b1 - b2) * 0.5;
    // stage 1 inverse
    [
        (a0 + a7) * 0.5,
        (a1 + a6) * 0.5,
        (a2 + a5) * 0.5,
        (a3 + a4) * 0.5,
        (a3 - a4) * 0.5,
        (a2 - a5) * 0.5,
        (a1 - a6) * 0.5,
        (a0 - a7) * 0.5,
    ]
}

/// Apply a lane-wide 1-D transform separably over the batch (columns then
/// rows within each lane's 8x8 block — mirror of `loeffler::separable_2d`).
pub fn separable_2d_lanes<const W: usize, R: LaneRotors<W>>(
    r: &R,
    batch: &mut BlockBatch<W>,
    f: fn(&R, &[LanesN<W>; 8]) -> [LanesN<W>; 8],
) {
    // columns
    for j in 0..8 {
        let col: [LanesN<W>; 8] =
            std::array::from_fn(|i| batch.data[i * 8 + j]);
        let out = f(r, &col);
        for i in 0..8 {
            batch.data[i * 8 + j] = out[i];
        }
    }
    // rows
    for i in 0..8 {
        let row: [LanesN<W>; 8] =
            std::array::from_fn(|j| batch.data[i * 8 + j]);
        let out = f(r, &row);
        for j in 0..8 {
            batch.data[i * 8 + j] = out[j];
        }
    }
}

/// Lane-wide separable matrix DCT forward (`B <- D B D^T`), mirroring the
/// scalar `MatrixDct::forward` accumulation order per lane.
pub fn matrix_forward_lanes<const W: usize>(
    d: &[[f32; 8]; 8],
    batch: &mut BlockBatch<W>,
) {
    let mut tmp = [LanesN::<W>::ZERO; 64];
    // columns: tmp = D * B
    for k in 0..8 {
        for j in 0..8 {
            let mut acc = LanesN::<W>::ZERO;
            for n in 0..8 {
                acc = acc + batch.data[n * 8 + j] * d[k][n];
            }
            tmp[k * 8 + j] = acc;
        }
    }
    // rows: out = tmp * D^T
    for k in 0..8 {
        for l in 0..8 {
            let mut acc = LanesN::<W>::ZERO;
            for j in 0..8 {
                acc = acc + tmp[k * 8 + j] * d[l][j];
            }
            batch.data[k * 8 + l] = acc;
        }
    }
}

/// Lane-wide matrix IDCT (`B <- D^T B D`), mirroring the scalar
/// `MatrixDct::inverse` accumulation order per lane.
pub fn matrix_inverse_lanes<const W: usize>(
    d: &[[f32; 8]; 8],
    batch: &mut BlockBatch<W>,
) {
    let mut tmp = [LanesN::<W>::ZERO; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = LanesN::<W>::ZERO;
            for k in 0..8 {
                acc = acc + batch.data[k * 8 + j] * d[k][i];
            }
            tmp[i * 8 + j] = acc;
        }
    }
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = LanesN::<W>::ZERO;
            for l in 0..8 {
                acc = acc + tmp[i * 8 + l] * d[l][j];
            }
            batch.data[i * 8 + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Monomorphized transform dispatch
// ---------------------------------------------------------------------------

/// Statically dispatched transform: the batched replacement for the
/// `Box<dyn Transform8x8>` virtual call. Each arm owns the scalar
/// implementation (used for tail blocks) and drives the matching
/// lane-wide kernel for full batches at either width.
pub enum BatchTransform {
    /// Boxed: the 2x 8x8 f32 matrices would otherwise dominate the enum
    /// size carried by every engine.
    Matrix(Box<MatrixDct>),
    Loeffler(LoefflerDct),
    Cordic(CordicLoefflerDct),
    /// Integer fixed-point CORDIC-Loeffler (precision-parameterized).
    CordicFxp(CordicFxpDct),
    /// The textbook baseline has no lane kernel; full batches run the
    /// scalar transform once per lane (still bit-identical, never hot).
    Naive(NaiveDct),
}

impl BatchTransform {
    pub fn new(variant: Variant) -> BatchTransform {
        Self::with_precision(variant, FxpPrecision::default())
    }

    /// Build with an explicit fixed-point precision (only the
    /// `CordicFxp` arm consumes it).
    pub fn with_precision(
        variant: Variant,
        precision: FxpPrecision,
    ) -> BatchTransform {
        match variant {
            Variant::Dct => {
                BatchTransform::Matrix(Box::new(MatrixDct::new()))
            }
            Variant::Loeffler => {
                BatchTransform::Loeffler(LoefflerDct::new())
            }
            Variant::Cordic => {
                BatchTransform::Cordic(CordicLoefflerDct::default())
            }
            Variant::CordicFxp => {
                BatchTransform::CordicFxp(CordicFxpDct::new(precision))
            }
            Variant::Naive => BatchTransform::Naive(NaiveDct::new()),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchTransform::Matrix(t) => t.name(),
            BatchTransform::Loeffler(t) => t.name(),
            BatchTransform::Cordic(t) => t.name(),
            BatchTransform::CordicFxp(t) => t.name(),
            BatchTransform::Naive(t) => t.name(),
        }
    }

    /// Scalar forward for tail blocks (static dispatch per arm).
    #[inline]
    pub fn forward_scalar(&self, block: &mut [f32; 64]) {
        match self {
            BatchTransform::Matrix(t) => t.forward(block),
            BatchTransform::Loeffler(t) => t.forward(block),
            BatchTransform::Cordic(t) => t.forward(block),
            BatchTransform::CordicFxp(t) => t.forward(block),
            BatchTransform::Naive(t) => t.forward(block),
        }
    }

    /// Scalar inverse for tail blocks.
    #[inline]
    pub fn inverse_scalar(&self, block: &mut [f32; 64]) {
        match self {
            BatchTransform::Matrix(t) => t.inverse(block),
            BatchTransform::Loeffler(t) => t.inverse(block),
            BatchTransform::Cordic(t) => t.inverse(block),
            BatchTransform::CordicFxp(t) => t.inverse(block),
            BatchTransform::Naive(t) => t.inverse(block),
        }
    }

    /// Lane-wide forward over a full batch of either width.
    pub fn forward_batch<const W: usize>(&self, batch: &mut BlockBatch<W>) {
        match self {
            BatchTransform::Matrix(t) => {
                matrix_forward_lanes(t.coeffs(), batch)
            }
            BatchTransform::Loeffler(t) => {
                separable_2d_lanes(t.rotors(), batch, fwd8_lanes::<W, _>)
            }
            BatchTransform::Cordic(t) => {
                separable_2d_lanes(t.rotors(), batch, fwd8_lanes::<W, _>)
            }
            BatchTransform::CordicFxp(t) => t.forward_lanes(batch),
            BatchTransform::Naive(t) => {
                for l in 0..W {
                    let mut blk = batch.extract_lane(l);
                    t.forward(&mut blk);
                    batch.insert_lane(l, &blk);
                }
            }
        }
    }

    /// Lane-wide inverse over a full batch of either width.
    pub fn inverse_batch<const W: usize>(&self, batch: &mut BlockBatch<W>) {
        match self {
            BatchTransform::Matrix(t) => {
                matrix_inverse_lanes(t.coeffs(), batch)
            }
            BatchTransform::Loeffler(t) => {
                separable_2d_lanes(t.rotors(), batch, inv8_lanes::<W, _>)
            }
            BatchTransform::Cordic(t) => {
                separable_2d_lanes(t.rotors(), batch, inv8_lanes::<W, _>)
            }
            BatchTransform::CordicFxp(t) => t.inverse_lanes(batch),
            BatchTransform::Naive(t) => {
                for l in 0..W {
                    let mut blk = batch.extract_lane(l);
                    t.inverse(&mut blk);
                    batch.insert_lane(l, &blk);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Per-call working set of the batch engine (~15 KiB): two lane-major
/// batches plus a quantized batch at *each* compiled width, and the
/// scalar-tail buffers. Holding both widths keeps the pool non-generic
/// (pipelines and the coordinator cache don't care about the engine's
/// resolved width); an engine only touches its own width's buffers.
/// Held in a [`ScratchPool`] so repeated compress/decode calls (and the
/// coordinator worker across jobs) never re-allocate it.
pub struct BlockScratch {
    coef8: BlockBatch<LANES>,
    recon8: BlockBatch<LANES>,
    qc8: QBatch<LANES>,
    coef16: BlockBatch<LANES_WIDE>,
    recon16: BlockBatch<LANES_WIDE>,
    qc16: QBatch<LANES_WIDE>,
    block: [f32; 64],
    qblock: [i16; 64],
}

impl BlockScratch {
    pub fn new() -> BlockScratch {
        BlockScratch {
            coef8: BlockBatch::zeroed(),
            recon8: BlockBatch::zeroed(),
            qc8: QBatch::zeroed(),
            coef16: BlockBatch::zeroed(),
            recon16: BlockBatch::zeroed(),
            qc16: QBatch::zeroed(),
            block: [0.0; 64],
            qblock: [0; 64],
        }
    }
}

impl Default for BlockScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Width-indexed access to the scratch buffers: the engine's generic
/// row kernels borrow the batch trio matching their `W`.
trait ScratchLanes<const W: usize> {
    fn lanes(
        &mut self,
    ) -> (&mut BlockBatch<W>, &mut BlockBatch<W>, &mut QBatch<W>);
}

impl ScratchLanes<LANES> for BlockScratch {
    fn lanes(
        &mut self,
    ) -> (
        &mut BlockBatch<LANES>,
        &mut BlockBatch<LANES>,
        &mut QBatch<LANES>,
    ) {
        (&mut self.coef8, &mut self.recon8, &mut self.qc8)
    }
}

impl ScratchLanes<LANES_WIDE> for BlockScratch {
    fn lanes(
        &mut self,
    ) -> (
        &mut BlockBatch<LANES_WIDE>,
        &mut BlockBatch<LANES_WIDE>,
        &mut QBatch<LANES_WIDE>,
    ) {
        (&mut self.coef16, &mut self.recon16, &mut self.qc16)
    }
}

/// A small arena of [`BlockScratch`] buffers. Serial callers check out
/// one buffer per image; the parallel lane's band workers each check out
/// their own, so the pool grows to the high-water worker count and is
/// reused for every subsequent call.
#[derive(Default)]
pub struct ScratchPool {
    pool: Mutex<Vec<Box<BlockScratch>>>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Run `f` with a pooled scratch buffer, returning it afterwards.
    pub fn with<T>(&self, f: impl FnOnce(&mut BlockScratch) -> T) -> T {
        let mut s = self
            .pool
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_default();
        let out = f(&mut s);
        self.pool.lock().expect("scratch pool poisoned").push(s);
        out
    }

    /// Buffers currently parked in the pool (for tests).
    pub fn parked(&self) -> usize {
        self.pool.lock().expect("scratch pool poisoned").len()
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// The batched pipeline core shared by both CPU lanes (and, through the
/// stub backend, the GPU lane): walks each block row in batches of its
/// resolved lane width (scalar tail for `grid_width % W` remainders),
/// quantizing with one table and decoding with the exact matrix IDCT —
/// the same stages, in the same arithmetic order, as the scalar
/// pipelines it replaced. The width (8 or 16) is fixed per engine at
/// construction ([`BatchWidth::resolve`]); outputs are bit-identical
/// across widths.
///
/// # Examples
///
/// Transform + quantize one block row of an 8-aligned image, collecting
/// the planar interchange buffer, the fused zigzag stream, and the
/// reconstruction in a single pass:
///
/// ```
/// use cordic_dct::dct::batch::BatchEngine;
/// use cordic_dct::dct::quant::effective_qtable;
/// use cordic_dct::dct::Variant;
/// use cordic_dct::image::synthetic;
///
/// let img = synthetic::lena_like(32, 8, 1); // 4 blocks, one row
/// let engine = BatchEngine::new(Variant::Cordic, effective_qtable(50));
/// let mut qcoef = vec![0.0f32; 32 * 8];
/// let mut scanned = vec![0i16; 32 * 8];
/// let mut recon = cordic_dct::image::GrayImage::new(32, 8);
/// engine.with_scratch(|s| {
///     engine.forward_quant_row(
///         s, &img, 0, Some(&mut qcoef), 0,
///         Some(&mut scanned), Some((&mut recon, 0)),
///     );
/// });
/// // scan position 0 of block 0 is the quantized DC coefficient
/// assert_eq!(scanned[0] as f32, qcoef[0]);
/// ```
pub struct BatchEngine {
    transform: BatchTransform,
    decoder: MatrixDct,
    qtable: [f32; 64],
    width: usize,
    scratch: ScratchPool,
}

impl BatchEngine {
    pub fn new(variant: Variant, qtable: [f32; 64]) -> BatchEngine {
        Self::with_config(variant, qtable, EngineConfig::default())
    }

    /// Build with an explicit [`EngineConfig`] (lane width + fxp
    /// precision).
    pub fn with_config(
        variant: Variant,
        qtable: [f32; 64],
        cfg: EngineConfig,
    ) -> BatchEngine {
        BatchEngine {
            transform: BatchTransform::with_precision(
                variant,
                cfg.precision,
            ),
            decoder: MatrixDct::new(),
            qtable,
            width: cfg.width.resolve(),
            scratch: ScratchPool::new(),
        }
    }

    pub fn transform_name(&self) -> &'static str {
        self.transform.name()
    }

    pub fn qtable(&self) -> &[f32; 64] {
        &self.qtable
    }

    /// The resolved lane width this engine batches at (8 or 16).
    pub fn lane_width(&self) -> usize {
        self.width
    }

    /// Run `f` with a scratch buffer from this engine's arena.
    pub fn with_scratch<T>(
        &self,
        f: impl FnOnce(&mut BlockScratch) -> T,
    ) -> T {
        self.scratch.with(f)
    }

    /// Forward-transform + quantize one block row: read blocks
    /// `(0.., src_by)` of the 8-aligned `padded` image and, for each
    /// output that is given, write quantized coefficients into block
    /// row `dst_by` of the planar `qcoef` buffer, zigzag-ordered
    /// coefficients into block row `dst_by` of the contiguous `scanned`
    /// buffer (the fused [`quantize_zigzag_batch`] output the entropy
    /// encoder consumes directly), and the decoded pixels into block
    /// row `recon.1` of `recon.0` (dequantize + exact matrix IDCT).
    /// Passing `qcoef: None` skips the planar interchange buffer
    /// entirely (the fused analyze path).
    ///
    /// Quantization runs once per block, fused with the zigzag reorder;
    /// the planar buffer and the reconstruction are derived from the
    /// scan-ordered batch through the inverse scan map, so all outputs
    /// stay bit-identical to the historical quantize-then-scatter path.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_quant_row(
        &self,
        s: &mut BlockScratch,
        padded: &GrayImage,
        src_by: usize,
        qcoef: Option<&mut [f32]>,
        dst_by: usize,
        scanned: Option<&mut [i16]>,
        recon: Option<(&mut GrayImage, usize)>,
    ) {
        match self.width {
            LANES_WIDE => self.forward_quant_row_w::<LANES_WIDE>(
                s, padded, src_by, qcoef, dst_by, scanned, recon,
            ),
            _ => self.forward_quant_row_w::<LANES>(
                s, padded, src_by, qcoef, dst_by, scanned, recon,
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_quant_row_w<const W: usize>(
        &self,
        s: &mut BlockScratch,
        padded: &GrayImage,
        src_by: usize,
        mut qcoef: Option<&mut [f32]>,
        dst_by: usize,
        mut scanned: Option<&mut [i16]>,
        mut recon: Option<(&mut GrayImage, usize)>,
    ) where
        BlockScratch: ScratchLanes<W>,
    {
        let w = padded.width;
        debug_assert!(w % BLOCK == 0);
        let gw = w / BLOCK;
        let mut bx = 0;
        while bx + W <= gw {
            let (coef, recon_b, qc) =
                <BlockScratch as ScratchLanes<W>>::lanes(s);
            gather(coef, padded, bx, src_by, W);
            self.transform.forward_batch(coef);
            quantize_zigzag_batch(coef, &self.qtable, qc);
            if let Some(out) = qcoef.as_mut() {
                scatter_coef_scan(qc, out, w, bx, dst_by, W);
            }
            if let Some(out) = scanned.as_mut() {
                scatter_scan(qc, out, gw, bx, dst_by, W);
            }
            if let Some((img, rby)) = recon.as_mut() {
                dequantize_scan_batch(qc, &self.qtable, recon_b);
                matrix_inverse_lanes(self.decoder.coeffs(), recon_b);
                scatter_blocks(recon_b, img, bx, *rby, W);
            }
            bx += W;
        }
        // scalar tail: the exact seed-path per-block sequence
        while bx < gw {
            extract_block(padded, bx, src_by, &mut s.block);
            self.transform.forward_scalar(&mut s.block);
            quantize_block(&s.block, &self.qtable, &mut s.qblock);
            if let Some(out) = qcoef.as_mut() {
                store_coef_planar(out, w, bx, dst_by, &s.qblock);
            }
            if let Some(out) = scanned.as_mut() {
                let base = (dst_by * gw + bx) * 64;
                out[base..base + 64]
                    .copy_from_slice(&zigzag_scan(&s.qblock));
            }
            if let Some((img, rby)) = recon.as_mut() {
                dequantize_block(&s.qblock, &self.qtable, &mut s.block);
                self.decoder.inverse(&mut s.block);
                store_block(img, bx, *rby, &s.block);
            }
            bx += 1;
        }
    }

    /// Decode one block row of a planar coefficient buffer (dequantize +
    /// exact matrix IDCT) into block row `dst_by` of `img`.
    pub fn decode_row(
        &self,
        s: &mut BlockScratch,
        qcoef: &[f32],
        width: usize,
        src_by: usize,
        img: &mut GrayImage,
        dst_by: usize,
    ) {
        match self.width {
            LANES_WIDE => self.decode_row_w::<LANES_WIDE>(
                s, qcoef, width, src_by, img, dst_by,
            ),
            _ => self.decode_row_w::<LANES>(
                s, qcoef, width, src_by, img, dst_by,
            ),
        }
    }

    fn decode_row_w<const W: usize>(
        &self,
        s: &mut BlockScratch,
        qcoef: &[f32],
        width: usize,
        src_by: usize,
        img: &mut GrayImage,
        dst_by: usize,
    ) where
        BlockScratch: ScratchLanes<W>,
    {
        debug_assert!(width % BLOCK == 0);
        let gw = width / BLOCK;
        let mut bx = 0;
        while bx + W <= gw {
            let (_, recon_b, qc) =
                <BlockScratch as ScratchLanes<W>>::lanes(s);
            gather_coef(qcoef, width, bx, src_by, W, qc);
            dequantize_batch(qc, &self.qtable, recon_b);
            matrix_inverse_lanes(self.decoder.coeffs(), recon_b);
            scatter_blocks(recon_b, img, bx, dst_by, W);
            bx += W;
        }
        while bx < gw {
            load_coef_planar(qcoef, width, bx, src_by, &mut s.qblock);
            dequantize_block(&s.qblock, &self.qtable, &mut s.block);
            self.decoder.inverse(&mut s.block);
            store_block(img, bx, dst_by, &s.block);
            bx += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::zigzag;
    use crate::dct::quant::effective_qtable;
    use crate::image::synthetic;
    use crate::util::prng::Rng;

    fn rand_batch_w<const W: usize>(seed: u64) -> BlockBatch<W> {
        let mut rng = Rng::new(seed);
        let mut b = BlockBatch::<W>::zeroed();
        for e in b.data.iter_mut() {
            for v in e.0.iter_mut() {
                *v = rng.range_f64(-128.0, 128.0) as f32;
            }
        }
        b
    }

    fn rand_batch(seed: u64) -> BlockBatch8 {
        rand_batch_w::<LANES>(seed)
    }

    const ALL_VARIANTS: [Variant; 5] = [
        Variant::Dct,
        Variant::Loeffler,
        Variant::Cordic,
        Variant::CordicFxp,
        Variant::Naive,
    ];

    #[test]
    fn lane_extract_insert_roundtrip() {
        let b = rand_batch(1);
        let mut c = BlockBatch8::zeroed();
        for l in 0..LANES {
            let blk = b.extract_lane(l);
            c.insert_lane(l, &blk);
        }
        assert_eq!(b, c);
    }

    #[test]
    fn forward_batch_matches_scalar_per_lane() {
        for variant in ALL_VARIANTS {
            let bt = BatchTransform::new(variant);
            let scalar = variant.transform();
            let mut batch = rand_batch(7);
            let blocks: Vec<[f32; 64]> =
                (0..LANES).map(|l| batch.extract_lane(l)).collect();
            bt.forward_batch(&mut batch);
            for (l, blk) in blocks.iter().enumerate() {
                let mut want = *blk;
                scalar.forward(&mut want);
                let got = batch.extract_lane(l);
                assert_eq!(
                    got[..],
                    want[..],
                    "{} lane {l} diverged",
                    bt.name()
                );
            }
        }
    }

    #[test]
    fn inverse_batch_matches_scalar_per_lane() {
        for variant in ALL_VARIANTS {
            let bt = BatchTransform::new(variant);
            let scalar = variant.transform();
            let mut batch = rand_batch(11);
            let blocks: Vec<[f32; 64]> =
                (0..LANES).map(|l| batch.extract_lane(l)).collect();
            bt.inverse_batch(&mut batch);
            for (l, blk) in blocks.iter().enumerate() {
                let mut want = *blk;
                scalar.inverse(&mut want);
                let got = batch.extract_lane(l);
                assert_eq!(got[..], want[..], "{} lane {l}", bt.name());
            }
        }
    }

    #[test]
    fn wide_batch_matches_scalar_per_lane() {
        // the 16-wide instantiation runs the same per-lane op sequence
        for variant in ALL_VARIANTS {
            let bt = BatchTransform::new(variant);
            let scalar = variant.transform();
            let mut batch = rand_batch_w::<LANES_WIDE>(13);
            let blocks: Vec<[f32; 64]> = (0..LANES_WIDE)
                .map(|l| batch.extract_lane(l))
                .collect();
            bt.forward_batch(&mut batch);
            for (l, blk) in blocks.iter().enumerate() {
                let mut want = *blk;
                scalar.forward(&mut want);
                assert_eq!(
                    batch.extract_lane(l)[..],
                    want[..],
                    "{} wide lane {l} diverged",
                    bt.name()
                );
            }
        }
    }

    #[test]
    fn quantize_batch_matches_scalar() {
        let q = effective_qtable(35);
        let batch = rand_batch(3);
        let mut qb = QBatch8::zeroed();
        quantize_batch(&batch, &q, &mut qb);
        let mut deq = BlockBatch8::zeroed();
        dequantize_batch(&qb, &q, &mut deq);
        for l in 0..LANES {
            let blk = batch.extract_lane(l);
            let mut want = [0i16; 64];
            quantize_block(&blk, &q, &mut want);
            for i in 0..64 {
                assert_eq!(qb.data[i][l], want[i], "lane {l} coef {i}");
            }
            let mut wantd = [0.0f32; 64];
            dequantize_block(&want, &q, &mut wantd);
            assert_eq!(deq.extract_lane(l)[..], wantd[..]);
        }
    }

    #[test]
    fn fused_zigzag_matches_quantize_then_scan() {
        let q = effective_qtable(50);
        let batch = rand_batch(4);
        let mut fused = QBatch8::zeroed();
        quantize_zigzag_batch(&batch, &q, &mut fused);
        for l in 0..LANES {
            let blk = batch.extract_lane(l);
            let mut qc = [0i16; 64];
            quantize_block(&blk, &q, &mut qc);
            let z = zigzag::scan(&qc);
            for k in 0..64 {
                assert_eq!(fused.data[k][l], z[k], "lane {l} scan {k}");
            }
        }
    }

    #[test]
    fn scan_order_scatters_match_row_major() {
        let q = effective_qtable(50);
        let batch = rand_batch(21);
        let mut qb_row = QBatch8::zeroed();
        let mut qb_scan = QBatch8::zeroed();
        quantize_batch(&batch, &q, &mut qb_row);
        quantize_zigzag_batch(&batch, &q, &mut qb_scan);
        // planar scatter from the scan-ordered batch == row-major scatter
        let mut via_row = vec![0.0f32; 64 * 8];
        let mut via_scan = vec![0.0f32; 64 * 8];
        scatter_coef(&qb_row, &mut via_row, 64, 0, 0, LANES);
        scatter_coef_scan(&qb_scan, &mut via_scan, 64, 0, 0, LANES);
        assert_eq!(via_row, via_scan);
        // dequantize from scan order == dequantize from row-major
        let mut deq_row = BlockBatch8::zeroed();
        let mut deq_scan = BlockBatch8::zeroed();
        dequantize_batch(&qb_row, &q, &mut deq_row);
        dequantize_scan_batch(&qb_scan, &q, &mut deq_scan);
        assert_eq!(deq_row, deq_scan);
        // the contiguous scan buffer carries each lane's zigzag sequence
        let mut scanned = vec![0i16; 64 * LANES];
        scatter_scan(&qb_scan, &mut scanned, LANES, 0, 0, LANES);
        for l in 0..LANES {
            for k in 0..64 {
                assert_eq!(scanned[l * 64 + k], qb_scan.data[k][l]);
            }
        }
    }

    #[test]
    fn gather_matches_extract_block_and_zeroes_tail() {
        let img = synthetic::lena_like(48, 16, 5);
        let mut batch = rand_batch(9); // dirty start: gather must overwrite
        gather(&mut batch, &img, 0, 1, 3);
        let mut want = [0.0f32; 64];
        for l in 0..3 {
            extract_block(&img, l, 1, &mut want);
            assert_eq!(batch.extract_lane(l)[..], want[..]);
        }
        for l in 3..LANES {
            assert!(batch.extract_lane(l).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn scatter_blocks_matches_store_block() {
        let img = synthetic::lena_like(64, 8, 6);
        let mut batch = BlockBatch8::zeroed();
        gather(&mut batch, &img, 0, 0, LANES);
        let mut via_batch = GrayImage::new(64, 8);
        scatter_blocks(&batch, &mut via_batch, 0, 0, LANES);
        let mut via_scalar = GrayImage::new(64, 8);
        let mut blk = [0.0f32; 64];
        for bx in 0..LANES {
            extract_block(&img, bx, 0, &mut blk);
            store_block(&mut via_scalar, bx, 0, &blk);
        }
        assert_eq!(via_batch, via_scalar);
        assert_eq!(via_batch, img);
    }

    #[test]
    fn coef_gather_scatter_roundtrip_with_tail() {
        let width = 40; // 5 blocks: one tail-sized batch
        let mut rng = Rng::new(12);
        let mut qb = QBatch8::zeroed();
        for e in qb.data.iter_mut() {
            for v in e.iter_mut().take(5) {
                *v = rng.range_i64(-512, 512) as i16;
            }
        }
        let mut buf = vec![0.0f32; width * 8];
        scatter_coef(&qb, &mut buf, width, 0, 0, 5);
        let mut back = QBatch8::zeroed();
        gather_coef(&buf, width, 0, 0, 5, &mut back);
        assert_eq!(qb, back);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let pool = ScratchPool::new();
        pool.with(|s| s.block[0] = 1.0);
        assert_eq!(pool.parked(), 1);
        pool.with(|s| assert_eq!(s.block[0], 1.0));
        assert_eq!(pool.parked(), 1);
    }

    #[test]
    fn batch_width_parse_and_resolve() {
        assert_eq!(BatchWidth::parse("auto"), Some(BatchWidth::Auto));
        assert_eq!(BatchWidth::parse("8"), Some(BatchWidth::W8));
        assert_eq!(BatchWidth::parse("16"), Some(BatchWidth::W16));
        assert_eq!(BatchWidth::parse("32"), None);
        assert_eq!(BatchWidth::W8.resolve(), LANES);
        assert_eq!(BatchWidth::W16.resolve(), LANES_WIDE);
        let auto = BatchWidth::Auto.resolve();
        assert!(auto == LANES || auto == LANES_WIDE);
    }

    #[test]
    fn engine_row_matches_seed_scalar_sequence() {
        let img = synthetic::cablecar_like(72, 8, 8); // 9 blocks: tail of 1
        let q = effective_qtable(50);
        let engine = BatchEngine::new(Variant::Cordic, q);
        let mut qcoef = vec![0.0f32; 72 * 8];
        let mut scanned = vec![0i16; 72 * 8];
        let mut recon = GrayImage::new(72, 8);
        engine.with_scratch(|s| {
            engine.forward_quant_row(
                s,
                &img,
                0,
                Some(&mut qcoef),
                0,
                Some(&mut scanned),
                Some((&mut recon, 0)),
            );
        });
        // seed-path reference
        let t = Variant::Cordic.transform();
        let dec = MatrixDct::new();
        let mut want_q = vec![0.0f32; 72 * 8];
        let mut want_s = vec![0i16; 72 * 8];
        let mut want_r = GrayImage::new(72, 8);
        let mut blk = [0.0f32; 64];
        let mut qc = [0i16; 64];
        for bx in 0..9 {
            extract_block(&img, bx, 0, &mut blk);
            t.forward(&mut blk);
            quantize_block(&blk, &q, &mut qc);
            store_coef_planar(&mut want_q, 72, bx, 0, &qc);
            want_s[bx * 64..(bx + 1) * 64]
                .copy_from_slice(&zigzag::scan(&qc));
            dequantize_block(&qc, &q, &mut blk);
            dec.inverse(&mut blk);
            store_block(&mut want_r, bx, 0, &blk);
        }
        assert_eq!(qcoef, want_q);
        assert_eq!(scanned, want_s);
        assert_eq!(recon, want_r);
        // decode side reproduces the same reconstruction
        let mut decoded = GrayImage::new(72, 8);
        engine.with_scratch(|s| {
            engine.decode_row(s, &qcoef, 72, 0, &mut decoded, 0);
        });
        assert_eq!(decoded, want_r);
    }

    #[test]
    fn wide_engine_rows_bit_identical_to_narrow() {
        // 18 blocks: W16 runs one 16-batch + 2 scalar tail; W8 runs two
        // 8-batches + 2 tail — outputs must match bit-for-bit anyway.
        let img = synthetic::lena_like(144, 8, 3);
        let q = effective_qtable(50);
        for variant in ALL_VARIANTS {
            let mk = |w: BatchWidth| {
                BatchEngine::with_config(
                    variant,
                    q,
                    EngineConfig {
                        width: w,
                        ..EngineConfig::default()
                    },
                )
            };
            let narrow = mk(BatchWidth::W8);
            let wide = mk(BatchWidth::W16);
            assert_eq!(narrow.lane_width(), LANES);
            assert_eq!(wide.lane_width(), LANES_WIDE);
            let mut out = Vec::new();
            for engine in [&narrow, &wide] {
                let mut qcoef = vec![0.0f32; 144 * 8];
                let mut scanned = vec![0i16; 144 * 8];
                let mut recon = GrayImage::new(144, 8);
                engine.with_scratch(|s| {
                    engine.forward_quant_row(
                        s,
                        &img,
                        0,
                        Some(&mut qcoef),
                        0,
                        Some(&mut scanned),
                        Some((&mut recon, 0)),
                    );
                });
                let mut decoded = GrayImage::new(144, 8);
                engine.with_scratch(|s| {
                    engine.decode_row(s, &qcoef, 144, 0, &mut decoded, 0);
                });
                out.push((qcoef, scanned, recon, decoded));
            }
            assert_eq!(out[0], out[1], "{variant:?} widths diverged");
        }
    }
}
