//! CORDIC rotation engine: micro-rotation planning and fixed-point
//! application — the arithmetic core of the paper's Cordic-based Loeffler
//! DCT (Sun/Heyne/Ruan/Goetze 2006).
//!
//! This mirrors `python/compile/kernels/transform8.py` bit-for-bit: the
//! same greedy plan, the same simulated fixed-point grid (`frac_bits`
//! fractional bits, round-half-even like `jnp.round`), the same gain
//! compensation, so the Rust CPU lane and the Pallas GPU lane compute the
//! same transform.

/// A planned CORDIC rotation: micro-rotation directions for a target angle
/// plus the accumulated magnitude gain.
#[derive(Clone, Debug)]
pub struct CordicPlan {
    pub theta: f64,
    pub sigmas: Vec<i8>,
    pub achieved: f64,
    pub gain: f64,
}

/// Greedy plan: sigma_i = +-1 choosing whichever direction moves the
/// accumulated angle toward `theta`; micro-rotation i has angle
/// atan(2^-i) and gain sqrt(1 + 4^-i).
pub fn plan(theta: f64, iters: usize) -> CordicPlan {
    let mut sigmas = Vec::with_capacity(iters);
    let mut phi = 0.0f64;
    let mut gain = 1.0f64;
    for i in 0..iters {
        let sigma: i8 = if phi < theta { 1 } else { -1 };
        sigmas.push(sigma);
        phi += sigma as f64 * (2.0f64.powi(-(i as i32))).atan();
        gain *= (1.0 + 4.0f64.powi(-(i as i32))).sqrt();
    }
    CordicPlan {
        theta,
        sigmas,
        achieved: phi,
        gain,
    }
}

/// Round `v` to `frac_bits` fractional bits, ties to even — the exact
/// behaviour of `jnp.round(v * s) / s` in the Pallas kernel.
///
/// Implemented with the magic-number trick: adding 1.5 * 2^23 to an f32
/// forces IEEE round-to-nearest-even at integer granularity; subtracting
/// restores the value. Valid for |v * 2^frac_bits| < 2^22, far above this
/// pipeline's coefficient range, and ~5x faster than the libm
/// `round_ties_even` call on baseline x86-64 (see EXPERIMENTS.md §Perf).
#[inline]
pub fn fxp(v: f32, frac_bits: u32) -> f32 {
    const MAGIC: f32 = 1.5 * (1u32 << 23) as f32;
    let s = (1u32 << frac_bits) as f32;
    debug_assert!((v * s).abs() < (1u32 << 22) as f32);
    ((v * s + MAGIC) - MAGIC) / s
}

/// [`fxp`] applied elementwise to a `W`-lane vector — the grid step of
/// the batched block engine at any lane width. Pure adds/muls, so the
/// autovectorizer maps it to vector instructions; per lane it is exactly
/// the scalar `fxp`.
#[inline]
pub fn fxp_lanes<const W: usize>(v: &mut [f32; W], frac_bits: u32) {
    for x in v.iter_mut() {
        *x = fxp(*x, frac_bits);
    }
}

/// [`fxp_lanes`] at the historical 8-lane width.
#[inline]
pub fn fxp8(v: &mut [f32; 8], frac_bits: u32) {
    fxp_lanes(v, frac_bits);
}

/// One fixed-point CORDIC rotator with gain compensation folded in, in the
/// flow graph's clockwise convention:
///
/// ```text
/// x' =  scale * ( x cos(theta) + y sin(theta) )
/// y' =  scale * (-x sin(theta) + y cos(theta) )
/// ```
#[derive(Clone, Debug)]
pub struct Rotator {
    plan: CordicPlan,
    /// Output gain compensation: scale / cordic_gain.
    comp: f32,
    /// Inverse-direction compensation: 1 / (scale * cordic_gain).
    comp_inv: f32,
    frac_bits: u32,
}

impl Rotator {
    pub fn new(theta: f64, scale: f64, iters: usize, frac_bits: u32) -> Self {
        let plan = plan(theta, iters);
        Rotator {
            comp: (scale / plan.gain) as f32,
            comp_inv: (1.0 / (scale * plan.gain)) as f32,
            plan,
            frac_bits,
        }
    }

    /// Residual angle error of the plan (radians).
    pub fn angle_error(&self) -> f64 {
        (self.plan.achieved - self.plan.theta).abs()
    }

    /// Shift-add operation count for the ablation table: 2 adds + 2
    /// shifts per micro-rotation + 2 compensation multiplies.
    pub fn ops(&self) -> (usize, usize) {
        (2, self.plan.sigmas.len() * 2)
    }

    /// Forward (clockwise) fixed-point rotation.
    #[inline]
    pub fn rotate_cw(&self, x: f32, y: f32) -> (f32, f32) {
        let fb = self.frac_bits;
        let mut x = fxp(x, fb);
        let mut y = fxp(y, fb);
        for (i, &sigma) in self.plan.sigmas.iter().enumerate() {
            let shift = 2.0f32.powi(-(i as i32));
            let s = sigma as f32;
            let xn = x + s * y * shift;
            let yn = y - s * x * shift;
            x = fxp(xn, fb);
            y = fxp(yn, fb);
        }
        (fxp(x * self.comp, fb), fxp(y * self.comp, fb))
    }

    /// Lane-wide forward rotation: [`Rotator::rotate_cw`] applied to `W`
    /// independent (x, y) pairs at once, micro-rotation-outer /
    /// lane-inner so every step is a `W`-wide add/mul the compiler can
    /// vectorize. Each lane performs the exact scalar op sequence.
    #[inline]
    pub fn rotate_cw_lanes<const W: usize>(
        &self,
        x: &mut [f32; W],
        y: &mut [f32; W],
    ) {
        let fb = self.frac_bits;
        fxp_lanes(x, fb);
        fxp_lanes(y, fb);
        for (i, &sigma) in self.plan.sigmas.iter().enumerate() {
            let shift = 2.0f32.powi(-(i as i32));
            let s = sigma as f32;
            for l in 0..W {
                let xn = x[l] + s * y[l] * shift;
                let yn = y[l] - s * x[l] * shift;
                x[l] = xn;
                y[l] = yn;
            }
            fxp_lanes(x, fb);
            fxp_lanes(y, fb);
        }
        for l in 0..W {
            x[l] = fxp(x[l] * self.comp, fb);
            y[l] = fxp(y[l] * self.comp, fb);
        }
    }

    /// [`Rotator::rotate_cw_lanes`] at the historical 8-lane width.
    #[inline]
    pub fn rotate_cw8(&self, x: &mut [f32; 8], y: &mut [f32; 8]) {
        self.rotate_cw_lanes(x, y);
    }

    /// Lane-wide inverse rotation ([`Rotator::rotate_ccw`] across `W`
    /// lanes, same layout as [`Rotator::rotate_cw_lanes`]).
    #[inline]
    pub fn rotate_ccw_lanes<const W: usize>(
        &self,
        x: &mut [f32; W],
        y: &mut [f32; W],
    ) {
        let fb = self.frac_bits;
        fxp_lanes(x, fb);
        fxp_lanes(y, fb);
        for (i, &sigma) in self.plan.sigmas.iter().enumerate() {
            let shift = 2.0f32.powi(-(i as i32));
            let s = sigma as f32;
            for l in 0..W {
                let xn = x[l] - s * y[l] * shift;
                let yn = y[l] + s * x[l] * shift;
                x[l] = xn;
                y[l] = yn;
            }
            fxp_lanes(x, fb);
            fxp_lanes(y, fb);
        }
        for l in 0..W {
            x[l] = fxp(x[l] * self.comp_inv, fb);
            y[l] = fxp(y[l] * self.comp_inv, fb);
        }
    }

    /// [`Rotator::rotate_ccw_lanes`] at the historical 8-lane width.
    #[inline]
    pub fn rotate_ccw8(&self, x: &mut [f32; 8], y: &mut [f32; 8]) {
        self.rotate_ccw_lanes(x, y);
    }

    /// Inverse (counterclockwise) fixed-point rotation.
    #[inline]
    pub fn rotate_ccw(&self, x: f32, y: f32) -> (f32, f32) {
        let fb = self.frac_bits;
        let mut x = fxp(x, fb);
        let mut y = fxp(y, fb);
        for (i, &sigma) in self.plan.sigmas.iter().enumerate() {
            let shift = 2.0f32.powi(-(i as i32));
            let s = sigma as f32;
            let xn = x - s * y * shift;
            let yn = y + s * x * shift;
            x = fxp(xn, fb);
            y = fxp(yn, fb);
        }
        (fxp(x * self.comp_inv, fb), fxp(y * self.comp_inv, fb))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A3: f64 = 3.0 * std::f64::consts::PI / 16.0;
    const A1: f64 = std::f64::consts::PI / 16.0;
    const A6: f64 = 6.0 * std::f64::consts::PI / 16.0;

    #[test]
    fn plan_angle_error_bounded() {
        for theta in [A1, A3, A6] {
            for iters in [2usize, 3, 4, 8] {
                let p = plan(theta, iters);
                let bound =
                    (2.0f64.powi(-(iters as i32 - 1))).atan() + 1e-12;
                assert!(
                    (p.achieved - theta).abs() <= bound,
                    "theta {theta} iters {iters}"
                );
            }
        }
    }

    #[test]
    fn plan_gain_matches_formula() {
        let p = plan(0.7, 5);
        let want: f64 = (0..5)
            .map(|i| (1.0 + 4.0f64.powi(-i)).sqrt())
            .product();
        assert!((p.gain - want).abs() < 1e-12);
    }

    #[test]
    fn fxp_round_half_even() {
        // at frac_bits=1, grid is halves: 0.25 is a tie between 0.0, 0.5
        assert_eq!(fxp(0.25, 1), 0.0); // ties to even (0.0)
        assert_eq!(fxp(0.75, 1), 1.0); // ties to even (1.0)
        assert_eq!(fxp(0.26, 1), 0.5);
        assert_eq!(fxp(-0.25, 1), -0.0);
    }

    #[test]
    fn rotation_approximates_exact() {
        let r = Rotator::new(A3, 1.0, 4, 14);
        let (x, y) = (0.7f32, -0.2f32);
        let (gx, gy) = r.rotate_cw(x, y);
        let (c, s) = (A3.cos() as f32, A3.sin() as f32);
        let (ex, ey) = (x * c + y * s, -x * s + y * c);
        let err = (gx - ex).abs().max((gy - ey).abs());
        let bound = (2.0f32.powi(-3)).atan() * 1.0 + 0.01;
        assert!(err < bound, "err {err}");
    }

    #[test]
    fn ccw_inverts_cw_approximately() {
        let r = Rotator::new(A6, std::f64::consts::SQRT_2, 4, 14);
        let (x, y) = (0.3f32, 0.9f32);
        let (fx, fy) = r.rotate_cw(x, y);
        let (bx, by) = r.rotate_ccw(fx, fy);
        assert!((bx - x).abs() < 5e-3, "{bx} vs {x}");
        assert!((by - y).abs() < 5e-3, "{by} vs {y}");
    }

    #[test]
    fn scale_applied() {
        let r = Rotator::new(0.0, 2.0, 4, 14);
        // theta 0 still runs micro-rotations that cancel; net must be
        // approximately scale * identity
        let (gx, gy) = r.rotate_cw(0.5, -0.25);
        assert!((gx - 1.0).abs() < 0.1, "{gx}");
        assert!((gy + 0.5).abs() < 0.1, "{gy}");
    }

    #[test]
    fn lane_wide_rotation_matches_scalar_bitwise() {
        for (theta, scale) in
            [(A1, 1.0), (A3, 1.0), (A6, std::f64::consts::SQRT_2)]
        {
            let r = Rotator::new(theta, scale, 3, 10);
            let mut x: [f32; 8] =
                std::array::from_fn(|l| 0.11 * l as f32 - 0.4);
            let mut y: [f32; 8] =
                std::array::from_fn(|l| -0.07 * l as f32 + 0.3);
            let (sx, sy) = (x, y);
            r.rotate_cw8(&mut x, &mut y);
            for l in 0..8 {
                let (ex, ey) = r.rotate_cw(sx[l], sy[l]);
                assert_eq!((x[l], y[l]), (ex, ey), "cw lane {l}");
            }
            let (sx, sy) = (x, y);
            let mut bx = x;
            let mut by = y;
            r.rotate_ccw8(&mut bx, &mut by);
            for l in 0..8 {
                let (ex, ey) = r.rotate_ccw(sx[l], sy[l]);
                assert_eq!((bx[l], by[l]), (ex, ey), "ccw lane {l}");
            }
        }
    }

    #[test]
    fn fxp8_matches_fxp() {
        let mut v: [f32; 8] =
            std::array::from_fn(|l| 0.123 * l as f32 - 0.345);
        let orig = v;
        fxp8(&mut v, 10);
        for l in 0..8 {
            assert_eq!(v[l], fxp(orig[l], 10));
        }
    }

    #[test]
    fn coarser_grid_larger_error() {
        let fine = Rotator::new(A3, 1.0, 6, 14);
        let coarse = Rotator::new(A3, 1.0, 2, 6);
        let exact = |x: f32, y: f32| {
            let (c, s) = (A3.cos() as f32, A3.sin() as f32);
            (x * c + y * s, -x * s + y * c)
        };
        let (x, y) = (0.9f32, 0.4f32);
        let e = exact(x, y);
        let f = fine.rotate_cw(x, y);
        let c = coarse.rotate_cw(x, y);
        let err_f = (f.0 - e.0).abs() + (f.1 - e.1).abs();
        let err_c = (c.0 - e.0).abs() + (c.1 - e.1).abs();
        assert!(err_f < err_c, "fine {err_f} coarse {err_c}");
    }
}
