//! Integer fixed-point CORDIC-Loeffler DCT — the hardware-oriented
//! datapath of "Generic-Precision algorithm for DCT-Cordic
//! architectures" (PAPERS.md), as a precision-parameterized lane.
//!
//! Where [`super::cordic_loeffler`] *simulates* fixed-point on f32 (so
//! the CPU lane matches the Pallas GPU kernel bit-for-bit), this module
//! runs the real integer datapath: signals live on a Q(`frac_bits`)
//! grid, each CORDIC micro-rotation is a true shift-add
//! (`x += s * (y >> i)`), and gain compensation / graph constants are
//! Q15 multiplies — the multiplier-free rotator structure the
//! Generic-Precision paper synthesizes, with the precision knob
//! ([`FxpPrecision`]: micro-rotation count + fraction bits) exposed all
//! the way up to the CLI (`--variant cordic-fxp --precision N`).
//!
//! Lanes are carried in `i32` (the accumulator width; intermediate
//! butterfly sums exceed the i16 range at full pixel swing) while the
//! post-normalization outputs and the quantized coefficients fit i16 —
//! matching a 16-bit hardware datapath with wider adders. The kernel is
//! width-generic: the scalar [`Transform8x8`] path is the `W = 1`
//! instantiation of the same lane code, so the batched 8- and 16-wide
//! paths are bit-identical to scalar by construction. Reconstruction
//! quality is precision-bound (locked by `tests/fxp_psnr.rs`), not
//! bit-parity-bound: the integer grid intentionally diverges from the
//! f32 lanes.

use super::batch::{BlockBatch, LanesN};
use super::cordic::plan;
use super::cordic_loeffler::{DEFAULT_FRAC_BITS, DEFAULT_ITERS};
use super::loeffler::{ANGLE_EVEN, ANGLE_ODD_A, ANGLE_ODD_B};
use super::Transform8x8;

/// Precision knob of the fixed-point lane: CORDIC micro-rotation count
/// and fractional bits of the Q grid (the two axes the Generic-Precision
/// paper sweeps). Defaults match the f32 CORDIC lane calibration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FxpPrecision {
    /// CORDIC micro-rotations per rotator (angle accuracy).
    pub iters: usize,
    /// Fractional bits of the Q(frac_bits) value grid (magnitude
    /// accuracy). Capped at 14 so ingest of ±2^11-range DCT signals
    /// stays clear of the i32 accumulator headroom.
    pub frac_bits: u32,
}

impl Default for FxpPrecision {
    fn default() -> Self {
        FxpPrecision {
            iters: DEFAULT_ITERS,
            frac_bits: DEFAULT_FRAC_BITS,
        }
    }
}

impl FxpPrecision {
    /// Map the CLI's single `--precision N` level (1..=8) onto both
    /// axes: N micro-rotations and `2N + 4` fraction bits (capped at
    /// 14). Level 3 is the default calibration.
    pub fn from_level(level: u32) -> FxpPrecision {
        let level = level.clamp(1, 8);
        FxpPrecision {
            iters: level as usize,
            frac_bits: (2 * level + 4).min(14),
        }
    }

    /// Clamp to the supported range (used by constructors so a wild
    /// config cannot overflow the integer datapath).
    pub fn clamped(self) -> FxpPrecision {
        FxpPrecision {
            iters: self.iters.clamp(1, 16),
            frac_bits: self.frac_bits.clamp(2, 14),
        }
    }
}

const Q15: f64 = 32768.0;

#[inline]
fn q15(v: f64) -> i32 {
    (v * Q15).round() as i32
}

/// Q15 rounding multiply: `round(v * c / 2^15)` with a half-LSB bias
/// add — the DSP `MPYR` shape.
#[inline]
fn mul_q15(v: i32, c: i32) -> i32 {
    ((v as i64 * c as i64 + (1 << 14)) >> 15) as i32
}

// -- lane helpers on [i32; W] ------------------------------------------------

#[inline]
fn ladd<const W: usize>(a: &[i32; W], b: &[i32; W]) -> [i32; W] {
    let mut out = [0i32; W];
    for l in 0..W {
        out[l] = a[l] + b[l];
    }
    out
}

#[inline]
fn lsub<const W: usize>(a: &[i32; W], b: &[i32; W]) -> [i32; W] {
    let mut out = [0i32; W];
    for l in 0..W {
        out[l] = a[l] - b[l];
    }
    out
}

#[inline]
fn lmul_q15<const W: usize>(a: &[i32; W], c: i32) -> [i32; W] {
    let mut out = [0i32; W];
    for l in 0..W {
        out[l] = mul_q15(a[l], c);
    }
    out
}

/// Halving on the integer grid (`>> 1`, the hardware wire shift).
#[inline]
fn lhalf<const W: usize>(a: &[i32; W]) -> [i32; W] {
    let mut out = [0i32; W];
    for l in 0..W {
        out[l] = a[l] >> 1;
    }
    out
}

/// One integer CORDIC rotator: true shift-add micro-rotations on the Q
/// grid plus a Q15 gain-compensation multiply per output.
struct FxpRotator {
    sigmas: Vec<i8>,
    comp_q15: i32,
    comp_inv_q15: i32,
}

impl FxpRotator {
    fn new(theta: f64, scale: f64, iters: usize) -> FxpRotator {
        let p = plan(theta, iters);
        FxpRotator {
            comp_q15: q15(scale / p.gain),
            comp_inv_q15: q15(1.0 / (scale * p.gain)),
            sigmas: p.sigmas,
        }
    }

    /// Forward (clockwise) rotation across `W` lanes.
    #[inline]
    fn rotate_cw<const W: usize>(&self, x: &mut [i32; W], y: &mut [i32; W]) {
        for (i, &sigma) in self.sigmas.iter().enumerate() {
            let s = sigma as i32;
            for l in 0..W {
                let xs = x[l] >> i;
                let ys = y[l] >> i;
                x[l] += s * ys;
                y[l] -= s * xs;
            }
        }
        for l in 0..W {
            x[l] = mul_q15(x[l], self.comp_q15);
            y[l] = mul_q15(y[l], self.comp_q15);
        }
    }

    /// Inverse (counterclockwise) rotation across `W` lanes.
    #[inline]
    fn rotate_ccw<const W: usize>(&self, x: &mut [i32; W], y: &mut [i32; W]) {
        for (i, &sigma) in self.sigmas.iter().enumerate() {
            let s = sigma as i32;
            for l in 0..W {
                let xs = x[l] >> i;
                let ys = y[l] >> i;
                x[l] -= s * ys;
                y[l] += s * xs;
            }
        }
        for l in 0..W {
            x[l] = mul_q15(x[l], self.comp_inv_q15);
            y[l] = mul_q15(y[l], self.comp_inv_q15);
        }
    }
}

/// The three Loeffler rotators plus the graph's Q15 scale constants.
struct FxpRotors {
    ra: FxpRotator,
    rb: FxpRotator,
    re: FxpRotator,
    sqrt2_q15: i32,
    inv_sqrt8_q15: i32,
    sqrt8_q15: i32,
    ir2_q15: i32,
}

impl FxpRotors {
    fn new(iters: usize) -> FxpRotors {
        let sqrt2 = std::f64::consts::SQRT_2;
        FxpRotors {
            ra: FxpRotator::new(ANGLE_ODD_A, 1.0, iters),
            rb: FxpRotator::new(ANGLE_ODD_B, 1.0, iters),
            re: FxpRotator::new(ANGLE_EVEN, sqrt2, iters),
            sqrt2_q15: q15(sqrt2),
            inv_sqrt8_q15: q15(1.0 / 8.0f64.sqrt()),
            sqrt8_q15: q15(8.0f64.sqrt()),
            ir2_q15: q15(1.0 / sqrt2),
        }
    }
}

#[inline]
fn rot_cw<const W: usize>(
    r: &FxpRotator,
    x: [i32; W],
    y: [i32; W],
) -> ([i32; W], [i32; W]) {
    let (mut a, mut b) = (x, y);
    r.rotate_cw(&mut a, &mut b);
    (a, b)
}

#[inline]
fn rot_ccw<const W: usize>(
    r: &FxpRotator,
    x: [i32; W],
    y: [i32; W],
) -> ([i32; W], [i32; W]) {
    let (mut a, mut b) = (x, y);
    r.rotate_ccw(&mut a, &mut b);
    (a, b)
}

/// Forward 8-point DCT-II on the integer grid — the Loeffler flow graph
/// of `loeffler::fwd8` with shift-add rotators and Q15 constants.
fn fwd8_fxp<const W: usize>(
    r: &FxpRotors,
    x: &[[i32; W]; 8],
) -> [[i32; W]; 8] {
    // stage 1
    let a0 = ladd(&x[0], &x[7]);
    let a1 = ladd(&x[1], &x[6]);
    let a2 = ladd(&x[2], &x[5]);
    let a3 = ladd(&x[3], &x[4]);
    let a7 = lsub(&x[0], &x[7]);
    let a6 = lsub(&x[1], &x[6]);
    let a5 = lsub(&x[2], &x[5]);
    let a4 = lsub(&x[3], &x[4]);
    // stage 2
    let b0 = ladd(&a0, &a3);
    let b1 = ladd(&a1, &a2);
    let b3 = lsub(&a0, &a3);
    let b2 = lsub(&a1, &a2);
    let (b4, b7) = rot_cw(&r.ra, a4, a7);
    let (b5, b6) = rot_cw(&r.rb, a5, a6);
    // stage 3
    let x0 = ladd(&b0, &b1);
    let x4 = lsub(&b0, &b1);
    let (x2, x6) = rot_cw(&r.re, b2, b3);
    let c4 = ladd(&b4, &b6);
    let c6 = lsub(&b4, &b6);
    let c7 = ladd(&b7, &b5);
    let c5 = lsub(&b7, &b5);
    // stage 4
    let x1 = ladd(&c4, &c7);
    let x7 = lsub(&c7, &c4);
    let x3 = lmul_q15(&c5, r.sqrt2_q15);
    let x5 = lmul_q15(&c6, r.sqrt2_q15);
    let n = r.inv_sqrt8_q15;
    [
        lmul_q15(&x0, n),
        lmul_q15(&x1, n),
        lmul_q15(&x2, n),
        lmul_q15(&x3, n),
        lmul_q15(&x4, n),
        lmul_q15(&x5, n),
        lmul_q15(&x6, n),
        lmul_q15(&x7, n),
    ]
}

/// Inverse of [`fwd8_fxp`] (mirror of `loeffler::inv8` on the grid;
/// halvings are hardware `>> 1` wire shifts).
fn inv8_fxp<const W: usize>(
    r: &FxpRotors,
    y: &[[i32; W]; 8],
) -> [[i32; W]; 8] {
    let s8 = r.sqrt8_q15;
    let x0 = lmul_q15(&y[0], s8);
    let x1 = lmul_q15(&y[1], s8);
    let x2 = lmul_q15(&y[2], s8);
    let x3 = lmul_q15(&y[3], s8);
    let x4 = lmul_q15(&y[4], s8);
    let x5 = lmul_q15(&y[5], s8);
    let x6 = lmul_q15(&y[6], s8);
    let x7 = lmul_q15(&y[7], s8);
    // stage 4 inverse
    let c4 = lhalf(&lsub(&x1, &x7));
    let c7 = lhalf(&ladd(&x1, &x7));
    let c5 = lmul_q15(&x3, r.ir2_q15);
    let c6 = lmul_q15(&x5, r.ir2_q15);
    // stage 3 odd inverse
    let b4 = lhalf(&ladd(&c4, &c6));
    let b6 = lhalf(&lsub(&c4, &c6));
    let b7 = lhalf(&ladd(&c7, &c5));
    let b5 = lhalf(&lsub(&c7, &c5));
    // stage 3 even inverse
    let b0 = lhalf(&ladd(&x0, &x4));
    let b1 = lhalf(&lsub(&x0, &x4));
    let (b2, b3) = rot_ccw(&r.re, x2, x6);
    // stage 2 odd inverse
    let (a4, a7) = rot_ccw(&r.ra, b4, b7);
    let (a5, a6) = rot_ccw(&r.rb, b5, b6);
    // stage 2 even inverse
    let a0 = lhalf(&ladd(&b0, &b3));
    let a3 = lhalf(&lsub(&b0, &b3));
    let a1 = lhalf(&ladd(&b1, &b2));
    let a2 = lhalf(&lsub(&b1, &b2));
    // stage 1 inverse
    [
        lhalf(&ladd(&a0, &a7)),
        lhalf(&ladd(&a1, &a6)),
        lhalf(&ladd(&a2, &a5)),
        lhalf(&ladd(&a3, &a4)),
        lhalf(&lsub(&a3, &a4)),
        lhalf(&lsub(&a2, &a5)),
        lhalf(&lsub(&a1, &a6)),
        lhalf(&lsub(&a0, &a7)),
    ]
}

/// Apply a 1-D integer transform separably (columns then rows), same
/// shape as `batch::separable_2d_lanes`.
fn separable_2d_fxp<const W: usize>(
    r: &FxpRotors,
    data: &mut [[i32; W]; 64],
    f: fn(&FxpRotors, &[[i32; W]; 8]) -> [[i32; W]; 8],
) {
    // columns
    for j in 0..8 {
        let col: [[i32; W]; 8] = std::array::from_fn(|i| data[i * 8 + j]);
        let out = f(r, &col);
        for i in 0..8 {
            data[i * 8 + j] = out[i];
        }
    }
    // rows
    for i in 0..8 {
        let row: [[i32; W]; 8] = std::array::from_fn(|j| data[i * 8 + j]);
        let out = f(r, &row);
        for j in 0..8 {
            data[i * 8 + j] = out[j];
        }
    }
}

/// The fixed-point CORDIC-Loeffler transform (`Variant::CordicFxp`):
/// f32 signals enter/leave once per 2-D transform; both separable
/// passes run entirely on the integer grid.
pub struct CordicFxpDct {
    rotors: FxpRotors,
    precision: FxpPrecision,
}

impl CordicFxpDct {
    pub fn new(precision: FxpPrecision) -> CordicFxpDct {
        let precision = precision.clamped();
        CordicFxpDct {
            rotors: FxpRotors::new(precision.iters),
            precision,
        }
    }

    pub fn precision(&self) -> FxpPrecision {
        self.precision
    }

    /// Run one 2-D integer transform over the batch: ingest each lane
    /// onto the Q grid (round-half-even), run both separable passes in
    /// i32, egress back to f32 (exact: division by a power of two).
    #[inline]
    fn run_lanes<const W: usize>(
        &self,
        batch: &mut BlockBatch<W>,
        f: fn(&FxpRotors, &[[i32; W]; 8]) -> [[i32; W]; 8],
    ) {
        let scale = (1i64 << self.precision.frac_bits) as f32;
        let mut data = [[0i32; W]; 64];
        for i in 0..64 {
            for l in 0..W {
                data[i][l] =
                    (batch.data[i].0[l] * scale).round_ties_even() as i32;
            }
        }
        separable_2d_fxp(&self.rotors, &mut data, f);
        let inv = 1.0 / scale;
        for i in 0..64 {
            for l in 0..W {
                batch.data[i].0[l] = data[i][l] as f32 * inv;
            }
        }
    }

    /// Lane-wide forward over a `W`-wide batch (used by
    /// `batch::BatchTransform`).
    pub(crate) fn forward_lanes<const W: usize>(
        &self,
        batch: &mut BlockBatch<W>,
    ) {
        self.run_lanes(batch, fwd8_fxp);
    }

    /// Lane-wide inverse over a `W`-wide batch.
    pub(crate) fn inverse_lanes<const W: usize>(
        &self,
        batch: &mut BlockBatch<W>,
    ) {
        self.run_lanes(batch, inv8_fxp);
    }
}

impl Default for CordicFxpDct {
    fn default() -> Self {
        Self::new(FxpPrecision::default())
    }
}

impl Transform8x8 for CordicFxpDct {
    fn name(&self) -> &'static str {
        "cordic-fxp"
    }

    /// Scalar forward = the `W = 1` instantiation of the lane kernel,
    /// so batch tails are bit-identical to full batches at any width.
    fn forward(&self, block: &mut [f32; 64]) {
        let mut b = BlockBatch::<1>::zeroed();
        for i in 0..64 {
            b.data[i] = LanesN([block[i]]);
        }
        self.forward_lanes(&mut b);
        for i in 0..64 {
            block[i] = b.data[i].0[0];
        }
    }

    fn inverse(&self, block: &mut [f32; 64]) {
        let mut b = BlockBatch::<1>::zeroed();
        for i in 0..64 {
            b.data[i] = LanesN([block[i]]);
        }
        self.inverse_lanes(&mut b);
        for i in 0..64 {
            block[i] = b.data[i].0[0];
        }
    }

    fn ops_per_block(&self) -> (usize, usize) {
        // Same accounting shape as the f32 CORDIC lane: per 1-D pass,
        // 29 butterfly adds + 2 shift-adds per micro-rotation per
        // rotator; multiplies are the 8 normalization + 2 sqrt2 + 6
        // gain-compensation Q15 products.
        let shift_adds = 3 * self.precision.iters * 2;
        (16 * 16, 16 * (29 + shift_adds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::matrix::MatrixDct;
    use crate::util::prng::Rng;

    fn rand_block(seed: u64) -> [f32; 64] {
        let mut rng = Rng::new(seed);
        std::array::from_fn(|_| rng.range_f64(-128.0, 128.0) as f32)
    }

    #[test]
    fn approximates_exact_dct_at_default_precision() {
        let c = CordicFxpDct::default();
        let m = MatrixDct::new();
        let mut a = rand_block(1);
        let mut b = a;
        c.forward(&mut a);
        m.forward(&mut b);
        let norm: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 0.3 * norm, "max_err {max_err} norm {norm}");
        // the approximation must be nonzero (it is an approximation)
        assert!(max_err > 1e-4);
    }

    #[test]
    fn dc_nearly_exact() {
        // DC path is rotator-free: constant block -> DC = 8 * value
        let c = CordicFxpDct::default();
        let mut b = [50.0f32; 64];
        c.forward(&mut b);
        assert!((b[0] - 400.0).abs() < 1.0, "DC {}", b[0]);
        for v in &b[1..] {
            assert!(v.abs() < 1.0);
        }
    }

    #[test]
    fn lanes_match_scalar_bitwise() {
        // W=8 and W=16 lane paths must equal the scalar (W=1) path
        // exactly: same integer op sequence per lane.
        let c = CordicFxpDct::default();
        for fwd in [true, false] {
            let mut batch = BlockBatch::<8>::zeroed();
            let mut wide = BlockBatch::<16>::zeroed();
            let mut rng = Rng::new(17);
            let blocks: Vec<[f32; 64]> = (0..8)
                .map(|_| {
                    std::array::from_fn(|_| {
                        rng.range_f64(-128.0, 128.0) as f32
                    })
                })
                .collect();
            for (l, blk) in blocks.iter().enumerate() {
                batch.insert_lane(l, blk);
                wide.insert_lane(l, blk);
                wide.insert_lane(l + 8, blk);
            }
            if fwd {
                c.forward_lanes(&mut batch);
                c.forward_lanes(&mut wide);
            } else {
                c.inverse_lanes(&mut batch);
                c.inverse_lanes(&mut wide);
            }
            for (l, blk) in blocks.iter().enumerate() {
                let mut want = *blk;
                if fwd {
                    c.forward(&mut want);
                } else {
                    c.inverse(&mut want);
                }
                assert_eq!(batch.extract_lane(l)[..], want[..]);
                assert_eq!(wide.extract_lane(l)[..], want[..]);
                assert_eq!(wide.extract_lane(l + 8)[..], want[..]);
            }
        }
    }

    #[test]
    fn self_roundtrip_small_error() {
        let c = CordicFxpDct::default();
        let orig = rand_block(2);
        let mut b = orig;
        c.forward(&mut b);
        c.inverse(&mut b);
        for i in 0..64 {
            assert!(
                (b[i] - orig[i]).abs() < 3.0,
                "{i}: {} vs {}",
                b[i],
                orig[i]
            );
        }
    }

    #[test]
    fn higher_precision_tightens_approximation() {
        let m = MatrixDct::new();
        let orig = rand_block(4);
        let mut exact = orig;
        m.forward(&mut exact);
        let err = |level: u32| -> f32 {
            let c = CordicFxpDct::new(FxpPrecision::from_level(level));
            let mut b = orig;
            c.forward(&mut b);
            b.iter()
                .zip(&exact)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt()
        };
        assert!(err(6) < err(3));
        assert!(err(3) < err(1));
    }

    #[test]
    fn precision_levels_clamped_and_ordered() {
        assert_eq!(FxpPrecision::from_level(3), FxpPrecision::default());
        assert_eq!(
            FxpPrecision::from_level(0),
            FxpPrecision::from_level(1)
        );
        assert_eq!(
            FxpPrecision::from_level(99),
            FxpPrecision::from_level(8)
        );
        let lo = FxpPrecision::from_level(1);
        let hi = FxpPrecision::from_level(8);
        assert!(lo.iters < hi.iters);
        assert!(lo.frac_bits < hi.frac_bits);
        let wild = FxpPrecision {
            iters: 99,
            frac_bits: 31,
        }
        .clamped();
        assert!(wild.iters <= 16 && wild.frac_bits <= 14);
    }
}
