//! Loeffler fast DCT: the 4-stage flow graph (paper §2.5.2) with exact
//! float rotators — 11 multiplies per 1-D transform against 64 for the
//! direct form. The Cordic variant swaps the rotators; the graph itself
//! lives here and is shared.

use super::Transform8x8;

pub const SQRT2: f32 = std::f32::consts::SQRT_2;
pub(crate) const INV_SQRT8: f32 = 0.353_553_39; // 1/sqrt(8)
pub(crate) const SQRT8: f32 = 2.828_427_1;

/// Rotator angles of the graph.
pub const ANGLE_ODD_A: f64 = 3.0 * std::f64::consts::PI / 16.0;
pub const ANGLE_ODD_B: f64 = std::f64::consts::PI / 16.0;
pub const ANGLE_EVEN: f64 = 6.0 * std::f64::consts::PI / 16.0;

/// The three plane rotations a Loeffler graph needs; implementations are
/// exact (this file) or CORDIC fixed-point (`cordic_loeffler`).
pub trait Rotors {
    /// rot(3pi/16) applied to (a4, a7).
    fn odd_a(&self, x: f32, y: f32) -> (f32, f32);
    /// rot(pi/16) applied to (a5, a6).
    fn odd_b(&self, x: f32, y: f32) -> (f32, f32);
    /// sqrt(2) * rot(6pi/16) applied to (b2, b3).
    fn even(&self, x: f32, y: f32) -> (f32, f32);
    /// Inverses of the above.
    fn odd_a_inv(&self, x: f32, y: f32) -> (f32, f32);
    fn odd_b_inv(&self, x: f32, y: f32) -> (f32, f32);
    fn even_inv(&self, x: f32, y: f32) -> (f32, f32);
    /// Quantize a value to the implementation's arithmetic grid (identity
    /// for exact float).
    fn grid(&self, v: f32) -> f32 {
        v
    }
}

/// Forward 8-point DCT-II via the Loeffler graph (verified against the
/// DCT matrix in tests; identical structure to
/// `python/compile/kernels/transform8.py::loeffler8_fwd`).
pub fn fwd8<R: Rotors>(r: &R, x: &[f32; 8]) -> [f32; 8] {
    // stage 1
    let a0 = x[0] + x[7];
    let a1 = x[1] + x[6];
    let a2 = x[2] + x[5];
    let a3 = x[3] + x[4];
    let a7 = x[0] - x[7];
    let a6 = x[1] - x[6];
    let a5 = x[2] - x[5];
    let a4 = x[3] - x[4];
    // stage 2
    let b0 = a0 + a3;
    let b1 = a1 + a2;
    let b3 = a0 - a3;
    let b2 = a1 - a2;
    let (b4, b7) = r.odd_a(a4, a7);
    let (b5, b6) = r.odd_b(a5, a6);
    // stage 3
    let x0 = b0 + b1;
    let x4 = b0 - b1;
    let (x2, x6) = r.even(b2, b3);
    let c4 = b4 + b6;
    let c6 = b4 - b6;
    let c7 = b7 + b5;
    let c5 = b7 - b5;
    // stage 4
    let x1 = c4 + c7;
    let x7 = c7 - c4;
    let rt2 = r.grid(SQRT2);
    let x3 = c5 * rt2;
    let x5 = c6 * rt2;
    let n = r.grid(INV_SQRT8);
    [
        x0 * n,
        x1 * n,
        x2 * n,
        x3 * n,
        x4 * n,
        x5 * n,
        x6 * n,
        x7 * n,
    ]
}

/// Inverse of [`fwd8`]: transposed graph, each stage inverted.
pub fn inv8<R: Rotors>(r: &R, y: &[f32; 8]) -> [f32; 8] {
    let s8 = r.grid(SQRT8);
    let x0 = y[0] * s8;
    let x1 = y[1] * s8;
    let x2 = y[2] * s8;
    let x3 = y[3] * s8;
    let x4 = y[4] * s8;
    let x5 = y[5] * s8;
    let x6 = y[6] * s8;
    let x7 = y[7] * s8;
    // stage 4 inverse
    let c4 = (x1 - x7) * 0.5;
    let c7 = (x1 + x7) * 0.5;
    let ir2 = r.grid(1.0 / SQRT2);
    let c5 = x3 * ir2;
    let c6 = x5 * ir2;
    // stage 3 odd inverse
    let b4 = (c4 + c6) * 0.5;
    let b6 = (c4 - c6) * 0.5;
    let b7 = (c7 + c5) * 0.5;
    let b5 = (c7 - c5) * 0.5;
    // stage 3 even inverse
    let b0 = (x0 + x4) * 0.5;
    let b1 = (x0 - x4) * 0.5;
    let (b2, b3) = r.even_inv(x2, x6);
    // stage 2 odd inverse
    let (a4, a7) = r.odd_a_inv(b4, b7);
    let (a5, a6) = r.odd_b_inv(b5, b6);
    // stage 2 even inverse
    let a0 = (b0 + b3) * 0.5;
    let a3 = (b0 - b3) * 0.5;
    let a1 = (b1 + b2) * 0.5;
    let a2 = (b1 - b2) * 0.5;
    // stage 1 inverse
    [
        (a0 + a7) * 0.5,
        (a1 + a6) * 0.5,
        (a2 + a5) * 0.5,
        (a3 + a4) * 0.5,
        (a3 - a4) * 0.5,
        (a2 - a5) * 0.5,
        (a1 - a6) * 0.5,
        (a0 - a7) * 0.5,
    ]
}

/// Apply a 1-D transform separably over an 8x8 block.
pub fn separable_2d<R: Rotors>(
    r: &R,
    block: &mut [f32; 64],
    f: fn(&R, &[f32; 8]) -> [f32; 8],
) {
    // columns
    for j in 0..8 {
        let col = std::array::from_fn(|i| block[i * 8 + j]);
        let out = f(r, &col);
        for i in 0..8 {
            block[i * 8 + j] = out[i];
        }
    }
    // rows
    for i in 0..8 {
        let row = std::array::from_fn(|j| block[i * 8 + j]);
        let out = f(r, &row);
        block[i * 8..i * 8 + 8].copy_from_slice(&out);
    }
}

/// Exact float rotators.
pub struct ExactRotors {
    ca: f32,
    sa: f32,
    cb: f32,
    sb: f32,
    ce: f32,
    se: f32,
}

impl ExactRotors {
    pub fn new() -> Self {
        ExactRotors {
            ca: ANGLE_ODD_A.cos() as f32,
            sa: ANGLE_ODD_A.sin() as f32,
            cb: ANGLE_ODD_B.cos() as f32,
            sb: ANGLE_ODD_B.sin() as f32,
            ce: (ANGLE_EVEN.cos() * std::f64::consts::SQRT_2) as f32,
            se: (ANGLE_EVEN.sin() * std::f64::consts::SQRT_2) as f32,
        }
    }
}

impl Default for ExactRotors {
    fn default() -> Self {
        Self::new()
    }
}

impl Rotors for ExactRotors {
    #[inline]
    fn odd_a(&self, x: f32, y: f32) -> (f32, f32) {
        (x * self.ca + y * self.sa, -x * self.sa + y * self.ca)
    }
    #[inline]
    fn odd_b(&self, x: f32, y: f32) -> (f32, f32) {
        (x * self.cb + y * self.sb, -x * self.sb + y * self.cb)
    }
    #[inline]
    fn even(&self, x: f32, y: f32) -> (f32, f32) {
        (x * self.ce + y * self.se, -x * self.se + y * self.ce)
    }
    #[inline]
    fn odd_a_inv(&self, x: f32, y: f32) -> (f32, f32) {
        (x * self.ca - y * self.sa, x * self.sa + y * self.ca)
    }
    #[inline]
    fn odd_b_inv(&self, x: f32, y: f32) -> (f32, f32) {
        (x * self.cb - y * self.sb, x * self.sb + y * self.cb)
    }
    #[inline]
    fn even_inv(&self, x: f32, y: f32) -> (f32, f32) {
        // inverse of sqrt2 * rot: rot(-theta) / sqrt2; constants already
        // carry the sqrt2, so divide by 2 (sqrt2^2)
        (
            (x * self.ce - y * self.se) * 0.5,
            (x * self.se + y * self.ce) * 0.5,
        )
    }
}

/// The Loeffler DCT with exact rotators.
pub struct LoefflerDct {
    rotors: ExactRotors,
}

impl LoefflerDct {
    pub fn new() -> Self {
        LoefflerDct {
            rotors: ExactRotors::new(),
        }
    }

    /// The exact rotators, for the lane-wide batch kernels.
    pub(crate) fn rotors(&self) -> &ExactRotors {
        &self.rotors
    }
}

impl Default for LoefflerDct {
    fn default() -> Self {
        Self::new()
    }
}

impl Transform8x8 for LoefflerDct {
    fn name(&self) -> &'static str {
        "loeffler"
    }

    fn forward(&self, block: &mut [f32; 64]) {
        separable_2d(&self.rotors, block, fwd8);
    }

    fn inverse(&self, block: &mut [f32; 64]) {
        separable_2d(&self.rotors, block, inv8);
    }

    fn ops_per_block(&self) -> (usize, usize) {
        // Loeffler 1-D: 11 multiplies, 29 additions; 16 1-D transforms
        // per block (+8 normalization multiplies per transform here).
        (16 * (11 + 8), 16 * 29)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::{dct_matrix, matrix::MatrixDct, Transform8x8};
    use crate::util::prng::Rng;

    fn rand8(seed: u64) -> [f32; 8] {
        let mut rng = Rng::new(seed);
        std::array::from_fn(|_| rng.range_f64(-100.0, 100.0) as f32)
    }

    #[test]
    fn fwd8_matches_matrix() {
        let r = ExactRotors::new();
        let d = dct_matrix();
        for seed in 0..10 {
            let x = rand8(seed);
            let got = fwd8(&r, &x);
            for k in 0..8 {
                let want: f32 = (0..8).map(|n| d[k][n] * x[n]).sum();
                assert!(
                    (got[k] - want).abs() < 1e-3,
                    "seed {seed} k {k}: {} vs {want}",
                    got[k]
                );
            }
        }
    }

    #[test]
    fn inv8_roundtrip() {
        let r = ExactRotors::new();
        for seed in 0..10 {
            let x = rand8(seed);
            let back = inv8(&r, &fwd8(&r, &x));
            for k in 0..8 {
                assert!((back[k] - x[k]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn block_matches_matrix_dct() {
        let l = LoefflerDct::new();
        let m = MatrixDct::new();
        let mut rng = Rng::new(3);
        let mut a = [0.0f32; 64];
        for v in &mut a {
            *v = rng.range_f64(-128.0, 128.0) as f32;
        }
        let mut b = a;
        l.forward(&mut a);
        m.forward(&mut b);
        for i in 0..64 {
            assert!((a[i] - b[i]).abs() < 2e-3, "{i}: {} vs {}", a[i], b[i]);
        }
    }

    #[test]
    fn block_inverse_roundtrip() {
        let l = LoefflerDct::new();
        let mut rng = Rng::new(4);
        let orig: [f32; 64] =
            std::array::from_fn(|_| rng.range_f64(-128.0, 128.0) as f32);
        let mut b = orig;
        l.forward(&mut b);
        l.inverse(&mut b);
        for i in 0..64 {
            assert!((b[i] - orig[i]).abs() < 2e-3);
        }
    }

    #[test]
    fn far_fewer_multiplies_than_naive() {
        let (m, _) = LoefflerDct::new().ops_per_block();
        let (mn, _) = crate::dct::naive::NaiveDct::new().ops_per_block();
        assert!(m * 10 < mn);
    }
}
