import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0DEC)


def natural_image(rng, h, w):
    """Synthetic image with a natural-ish (1/f) spectrum: double cumulative
    sum of white noise, normalized to 0..255 — the python-side stand-in for
    the Rust plasma generator."""
    t = np.cumsum(np.cumsum(rng.standard_normal((h, w)), axis=0), axis=1)
    t = (t - t.min()) / max(t.max() - t.min(), 1e-9) * 255.0
    return t.astype(np.float32)


@pytest.fixture(scope="session")
def lena_like(rng):
    return natural_image(rng, 64, 64)
