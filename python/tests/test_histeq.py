"""Histogram-equalization kernels (the Tables 1-2 caption workload)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import histeq, ref

dims = st.integers(1, 8).map(lambda n: n * 8)


def u8_img(seed, h, w):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (h, w)).astype(np.float32)


class TestHistogram:
    def test_matches_bincount(self):
        img = u8_img(1, 32, 40)
        got = np.asarray(histeq.histogram256(jnp.asarray(img)))
        want = np.bincount(img.astype(np.int64).ravel(), minlength=256)
        np.testing.assert_array_equal(got, want.astype(np.float32))

    def test_total_equals_pixels(self):
        img = u8_img(2, 16, 24)
        got = np.asarray(histeq.histogram256(jnp.asarray(img)))
        assert got.sum() == 16 * 24

    def test_constant_image(self):
        img = np.full((8, 8), 200.0, np.float32)
        got = np.asarray(histeq.histogram256(jnp.asarray(img)))
        assert got[200] == 64 and got.sum() == 64

    @given(h=dims, w=dims, seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis(self, h, w, seed):
        img = u8_img(seed, h, w)
        got = np.asarray(histeq.histogram256(jnp.asarray(img)))
        want = np.bincount(img.astype(np.int64).ravel(), minlength=256)
        np.testing.assert_array_equal(got, want.astype(np.float32))


class TestHisteq:
    def test_matches_ref(self):
        img = u8_img(3, 40, 32)
        got = np.asarray(histeq.histeq(jnp.asarray(img)))
        want = np.asarray(ref.histeq(jnp.asarray(img)))
        np.testing.assert_array_equal(got, want)

    def test_output_range(self):
        img = u8_img(4, 24, 24) * 0.3 + 100  # low-contrast image
        out = np.asarray(histeq.histeq(jnp.asarray(img)))
        assert out.min() >= 0 and out.max() <= 255

    def test_stretches_contrast(self):
        """Equalization of a low-contrast image must widen the range."""
        rng = np.random.default_rng(5)
        img = rng.integers(100, 140, (32, 32)).astype(np.float32)
        out = np.asarray(histeq.histeq(jnp.asarray(img)))
        assert out.max() - out.min() > (img.max() - img.min()) * 2

    def test_monotone_mapping(self):
        """Equalization is a monotone LUT: pixel ordering is preserved."""
        img = u8_img(6, 16, 16)
        out = np.asarray(histeq.histeq(jnp.asarray(img)))
        flat_in, flat_out = img.ravel(), out.ravel()
        order = np.argsort(flat_in, kind="stable")
        assert np.all(np.diff(flat_out[order]) >= -1e-6)

    def test_full_range_image_near_identity(self):
        """An already-uniform ramp stays (approximately) itself."""
        ramp = np.tile(np.arange(256, dtype=np.float32), (8, 1))
        out = np.asarray(histeq.histeq(jnp.asarray(ramp)))
        assert np.abs(out - ramp).max() <= 2.0
