"""L2 model entry points + AOT lowering (HLO text interchange)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


class TestModelEntries:
    def test_compress_entry(self, lena_like):
        rec, qc = model.compress(jnp.asarray(lena_like))
        assert rec.shape == lena_like.shape == qc.shape

    def test_unfused_matches_fused_psnr(self, lena_like):
        img = jnp.asarray(lena_like)
        rec_f, _ = model.compress(img, quality=50)
        rec_u, _ = model.compress_unfused(img, quality=50)
        p_f = float(ref.psnr(img, rec_f))
        p_u = float(ref.psnr(img, rec_u))
        assert p_f == pytest.approx(p_u, abs=0.05)

    def test_dct_idct_entries_compose(self, lena_like):
        img = jnp.asarray(lena_like)
        (coef,) = model.dct_only(img)
        (back,) = model.idct_only(coef)
        assert float(ref.psnr(img, back)) > 50.0

    def test_psnr_entry_shape(self, lena_like):
        a = jnp.asarray(lena_like)
        (p,) = model.psnr(a, a)
        assert p.shape == (1,)
        assert float(p[0]) == pytest.approx(ref.PSNR_CAP_DB)

    def test_histeq_entry(self, lena_like):
        (out,) = model.histeq(jnp.asarray(lena_like))
        assert out.shape == lena_like.shape

    def test_unknown_kind_raises(self):
        with pytest.raises(KeyError):
            model.entry("nope")


class TestAot:
    def test_artifact_list_covers_paper_sizes(self):
        names = {name for name, *_ in aot.artifact_list(50)}
        for h, w in aot.ALL_SIZES:
            assert f"compress_dct_{h}x{w}" in names
            assert f"compress_cordic_{h}x{w}" in names
            assert f"psnr_{h}x{w}" in names
            assert f"histeq_{h}x{w}" in names

    def test_sizes_are_block_aligned(self):
        for h, w in aot.ALL_SIZES:
            assert h % 8 == 0 and w % 8 == 0

    def test_emit_one_artifact(self, tmp_path):
        name = "compress_dct_200x200"
        man = aot.emit(str(tmp_path), 50, only=[name], verbose=False)
        assert len(man["artifacts"]) == 1
        entry = man["artifacts"][0]
        assert entry["name"] == name
        hlo = (tmp_path / entry["file"]).read_text()
        assert "ENTRY" in hlo and "HloModule" in hlo
        # text-format HLO must parse shapes for the declared inputs
        assert "f32[200,200]" in hlo
        mpath = tmp_path / "manifest.json"
        assert json.loads(mpath.read_text())["quality"] == 50

    def test_emitted_hlo_executes_in_process(self, tmp_path):
        """Round-trip the HLO text through xla_client compile+run — the
        same path the Rust PJRT client uses."""
        from jax._src.lib import xla_client as xc

        name = "psnr_200x200"
        man = aot.emit(str(tmp_path), 50, only=[name], verbose=False)
        text = (tmp_path / man["artifacts"][0]["file"]).read_text()
        # sanity: parameter count matches manifest
        assert len(man["artifacts"][0]["inputs"]) == 2
        assert text.count("parameter(") >= 2

    def test_manifest_schema(self, tmp_path):
        man = aot.emit(str(tmp_path), 50, only=["dct_dct_512x512"],
                       verbose=False)
        e = man["artifacts"][0]
        for key in ("name", "file", "inputs", "outputs", "kind", "sha256",
                    "bytes"):
            assert key in e, key
        assert e["inputs"][0]["shape"] == [512, 512]
