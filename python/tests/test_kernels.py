"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Every kernel is checked against ref.py over a sweep of shapes (hypothesis
generates strip counts / widths) and contents. Sizes stay modest because
interpret-mode Pallas is slow; shape coverage, not pixel count, is what
matters here.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import dct8x8, psnr, quantize, ref, transform8

dims = st.integers(1, 8).map(lambda n: n * 8)


def rand_img(seed, h, w, lo=0, hi=256):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, (h, w)).astype(np.float32)


class TestDct2d:
    @pytest.mark.parametrize("h,w", [(8, 8), (8, 64), (32, 16), (64, 64)])
    def test_matches_ref(self, h, w):
        img = rand_img(1, h, w) - 128.0
        got = np.asarray(dct8x8.dct2d(jnp.asarray(img)))
        want = np.asarray(ref.dct2d_blocks(jnp.asarray(img)))
        np.testing.assert_allclose(got, want, atol=1e-3)

    @given(h=dims, w=dims, seed=st.integers(0, 2**16))
    @settings(max_examples=12, deadline=None)
    def test_matches_ref_hypothesis(self, h, w, seed):
        img = rand_img(seed, h, w) - 128.0
        got = np.asarray(dct8x8.dct2d(jnp.asarray(img)))
        want = np.asarray(ref.dct2d_blocks(jnp.asarray(img)))
        np.testing.assert_allclose(got, want, atol=1e-3)

    def test_idct_roundtrip(self):
        img = rand_img(2, 40, 24) - 128.0
        coef = dct8x8.dct2d(jnp.asarray(img))
        back = np.asarray(dct8x8.idct2d(coef))
        np.testing.assert_allclose(back, img, atol=1e-3)

    def test_rejects_non_multiple_of_8(self):
        with pytest.raises(ValueError):
            dct8x8.dct2d(jnp.zeros((10, 16)))

    def test_parseval_energy(self):
        """Orthonormal transform preserves energy."""
        img = rand_img(3, 16, 16) - 128.0
        coef = np.asarray(dct8x8.dct2d(jnp.asarray(img)))
        assert np.sum(coef**2) == pytest.approx(np.sum(img**2), rel=1e-4)

    def test_cordic_variant_matches_ref(self):
        img = rand_img(4, 24, 40) - 128.0
        got = np.asarray(dct8x8.dct2d(jnp.asarray(img), variant="cordic"))
        rs = transform8.cordic_rotators()
        want = np.asarray(ref.loeffler2d_blocks(jnp.asarray(img), rs))
        np.testing.assert_allclose(got, want, atol=1e-3)

    def test_loeffler_variant_matches_matrix(self):
        img = rand_img(5, 16, 32) - 128.0
        got = np.asarray(dct8x8.dct2d(jnp.asarray(img), variant="loeffler"))
        want = np.asarray(ref.dct2d_blocks(jnp.asarray(img)))
        np.testing.assert_allclose(got, want, atol=1e-2)


class TestQuantize:
    @pytest.mark.parametrize("quality", [10, 50, 90])
    def test_matches_ref(self, quality):
        coef = rand_img(6, 16, 24, -500, 500)
        q = ref.effective_qtable(quality)
        got = np.asarray(quantize.quantize(jnp.asarray(coef),
                                           quality=quality))
        want = np.asarray(ref.quantize(jnp.asarray(coef), q))
        # round() ties can flip between backends; allow <=1 step on <0.1%
        diff = np.abs(got - want)
        assert (diff > 1).sum() == 0
        assert (diff > 0).mean() < 1e-3

    @pytest.mark.parametrize("quality", [10, 50, 90])
    def test_dequantize_matches_ref(self, quality):
        qc = np.round(rand_img(7, 16, 16, -30, 30))
        q = ref.effective_qtable(quality)
        got = np.asarray(quantize.dequantize(jnp.asarray(qc),
                                             quality=quality))
        want = np.asarray(ref.dequantize(jnp.asarray(qc), q))
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_quant_dequant_error_bounded(self):
        coef = rand_img(8, 24, 24, -200, 200)
        q = ref.effective_qtable(50)
        qc = quantize.quantize(jnp.asarray(coef), quality=50)
        deq = np.asarray(quantize.dequantize(qc, quality=50))
        qt = np.tile(q, (3, 3))
        assert np.all(np.abs(deq - coef) <= qt / 2 + 1e-3)

    def test_quality_extremes(self):
        assert ref.quant_table(1).max() == 255
        assert np.all(ref.quant_table(100) == 1)


class TestCompressFused:
    @pytest.mark.parametrize("variant", ["dct", "cordic"])
    def test_matches_ref_pipeline(self, variant, lena_like):
        img = jnp.asarray(lena_like)
        rec, qc = dct8x8.compress(img, variant=variant, quality=50)
        rec_r, qc_r = ref.compress_pipeline(img, 50, variant)
        # Tie-flips in round() may differ by 1 quant step on a tiny
        # fraction of coefficients; compare through PSNR + near-equality.
        assert float(jnp.mean(qc != qc_r)) < 1e-3
        p_k = float(ref.psnr(img, rec))
        p_r = float(ref.psnr(img, rec_r))
        assert p_k == pytest.approx(p_r, abs=0.05)

    def test_recon_in_range(self, lena_like):
        rec, _ = dct8x8.compress(jnp.asarray(lena_like))
        assert float(jnp.min(rec)) >= 0.0
        assert float(jnp.max(rec)) <= 255.0

    def test_quality_monotone(self, lena_like):
        img = jnp.asarray(lena_like)
        psnrs = []
        for q in (10, 50, 90):
            rec, _ = dct8x8.compress(img, quality=q)
            psnrs.append(float(ref.psnr(img, rec)))
        assert psnrs[0] < psnrs[1] < psnrs[2]

    def test_cordic_below_dct(self, lena_like):
        """The headline Table 3/4 property: Cordic-Loeffler PSNR sits below
        the exact DCT (approximate encoder, standard decoder)."""
        img = jnp.asarray(lena_like)
        rec_d, _ = dct8x8.compress(img, variant="dct", quality=50)
        rec_c, _ = dct8x8.compress(img, variant="cordic", quality=50)
        p_d = float(ref.psnr(img, rec_d))
        p_c = float(ref.psnr(img, rec_c))
        assert p_c < p_d
        assert 0.5 < p_d - p_c < 8.0

    @given(h=dims, w=dims, seed=st.integers(0, 2**16))
    @settings(max_examples=8, deadline=None)
    def test_shapes_hypothesis(self, h, w, seed):
        img = jnp.asarray(rand_img(seed, h, w))
        rec, qc = dct8x8.compress(img)
        assert rec.shape == (h, w) and qc.shape == (h, w)
        assert float(ref.psnr(img, rec)) > 25.0


class TestPsnrKernel:
    def test_matches_ref(self, lena_like):
        a = jnp.asarray(lena_like)
        b = jnp.clip(a + 3.0, 0, 255)
        got = float(psnr.psnr(a, b))
        want = float(ref.psnr(a, b))
        assert got == pytest.approx(want, abs=1e-3)

    def test_identical_images_capped(self, lena_like):
        a = jnp.asarray(lena_like)
        assert float(psnr.psnr(a, a)) == pytest.approx(ref.PSNR_CAP_DB)

    def test_known_value(self):
        a = jnp.zeros((8, 8))
        b = jnp.full((8, 8), 16.0)  # MSE=256 -> PSNR = 20log10(255/16)
        want = 20 * np.log10(255.0 / 16.0)
        assert float(psnr.psnr(a, b)) == pytest.approx(want, abs=1e-3)

    @given(h=dims, w=dims, seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_matches_ref(self, h, w, seed):
        a = jnp.asarray(rand_img(seed, h, w))
        b = jnp.asarray(rand_img(seed + 1, h, w))
        assert float(psnr.psnr(a, b)) == pytest.approx(
            float(ref.psnr(a, b)), abs=1e-2)

    def test_symmetry(self, lena_like):
        a = jnp.asarray(lena_like)
        b = jnp.clip(a * 0.9, 0, 255)
        assert float(psnr.psnr(a, b)) == pytest.approx(
            float(psnr.psnr(b, a)), abs=1e-4)
