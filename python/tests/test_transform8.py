"""Unit tests for the 8-point transform layer: flow graph vs DCT matrix,
CORDIC rotation accuracy, forward/inverse round trips."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import transform8 as t8


def vec8(seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(8)


class TestDctMatrix:
    def test_orthonormal(self):
        d = t8.dct_matrix()
        assert np.allclose(d @ d.T, np.eye(8), atol=1e-12)

    def test_dc_row(self):
        d = t8.dct_matrix()
        assert np.allclose(d[0], 1.0 / math.sqrt(8.0))

    def test_known_impulse(self):
        # DCT of a unit impulse at n=0 is the first column of D.
        d = t8.dct_matrix()
        x = np.zeros(8)
        x[0] = 1.0
        assert np.allclose(d @ x, d[:, 0])


class TestLoefflerExact:
    @pytest.mark.parametrize("seed", range(8))
    def test_matches_matrix(self, seed):
        x = vec8(seed)
        d = t8.dct_matrix()
        got = np.array(t8.loeffler8_fwd(list(x), t8.exact_rotators()))
        assert np.allclose(got, d @ x, atol=1e-9)

    @pytest.mark.parametrize("seed", range(8))
    def test_inverse_roundtrip(self, seed):
        x = vec8(seed)
        rs = t8.exact_rotators()
        y = t8.loeffler8_fwd(list(x), rs)
        back = np.array(t8.loeffler8_inv(y, rs))
        assert np.allclose(back, x, atol=1e-9)

    @given(st.lists(st.floats(-1e3, 1e3), min_size=8, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_matches_matrix_hypothesis(self, xs):
        x = np.array(xs)
        d = t8.dct_matrix()
        got = np.array(t8.loeffler8_fwd(list(x), t8.exact_rotators()))
        assert np.allclose(got, d @ x, atol=1e-6 * max(1.0, np.abs(x).max()))


class TestCordicPlan:
    @pytest.mark.parametrize("theta", [t8.ANGLE_ODD_A, t8.ANGLE_ODD_B,
                                       t8.ANGLE_EVEN])
    @pytest.mark.parametrize("iters", [2, 3, 4, 6, 10])
    def test_angle_converges(self, theta, iters):
        _sig, phi, _gain = t8.cordic_plan(theta, iters)
        # CORDIC residual angle error is bounded by the last micro-rotation.
        assert abs(phi - theta) <= math.atan(2.0 ** (-(iters - 1))) + 1e-12

    def test_gain_formula(self):
        _sig, _phi, gain = t8.cordic_plan(0.5, 5)
        expect = math.prod(math.sqrt(1 + 4.0 ** (-i)) for i in range(5))
        assert gain == pytest.approx(expect)

    @pytest.mark.parametrize("iters,frac", [(3, 10), (4, 12), (6, 14)])
    def test_rotation_accuracy_scales(self, iters, frac):
        """CORDIC rotation approaches the exact rotation as iters grow."""
        rng = np.random.default_rng(42)
        x, y = rng.standard_normal(2)
        rot_c = t8.Rotator(t8.ANGLE_ODD_A, mode="cordic", iters=iters,
                           frac_bits=frac)
        rot_e = t8.Rotator(t8.ANGLE_ODD_A)
        gx, gy = t8.rotate_cw(np.float64(x), np.float64(y), rot_c)
        ex, ey = t8.rotate_cw(x, y, rot_e)
        err = max(abs(float(gx) - ex), abs(float(gy) - ey))
        bound = math.atan(2.0 ** (-(iters - 1))) * 2.0 + 2.0 ** (-frac) * 8
        assert err < bound

    def test_rotation_preserves_norm_approximately(self):
        rot = t8.Rotator(t8.ANGLE_EVEN, scale=t8.SQRT2, mode="cordic",
                         iters=4, frac_bits=14)
        x, y = 0.7, -0.3
        gx, gy = t8.rotate_cw(np.float64(x), np.float64(y), rot)
        r_in = math.hypot(x, y) * t8.SQRT2
        r_out = math.hypot(float(gx), float(gy))
        assert r_out == pytest.approx(r_in, rel=0.05)

    def test_ccw_inverts_cw(self):
        rot = t8.Rotator(t8.ANGLE_ODD_B, mode="cordic", iters=4,
                         frac_bits=14)
        x, y = 0.25, -0.8
        fx, fy = t8.rotate_cw(np.float64(x), np.float64(y), rot)
        bx, by = t8.rotate_ccw(fx, fy, rot)
        assert float(bx) == pytest.approx(x, abs=2e-3)
        assert float(by) == pytest.approx(y, abs=2e-3)


class TestCordicLoeffler:
    @pytest.mark.parametrize("seed", range(4))
    def test_close_to_exact_dct(self, seed):
        """The Cordic variant approximates the DCT within the angle-error
        budget (this is exactly the approximation the PSNR tables probe)."""
        x = vec8(seed) * 100.0
        d = t8.dct_matrix()
        got = np.array(t8.loeffler8_fwd(list(x), t8.cordic_rotators()))
        ref = d @ x
        # Residual angle error of an n-iteration CORDIC rotator is bounded
        # by atan(2^-(n-1)); a rotation that is off by dtheta moves a vector
        # by at most 2*sin(dtheta/2)*|v|.
        import math
        dtheta = math.atan(2.0 ** (-(t8.cordic_rotators().odd_a.iters - 1)))
        bound = 2 * math.sin(dtheta / 2) * np.linalg.norm(x) + 1.0
        assert np.abs(got - ref).max() < bound
        # but NOT exactly equal — the approximation must be visible,
        # otherwise the Table 3/4 gap would vanish.
        assert np.abs(got - ref).max() > 1e-6

    def test_dc_is_exact_mean(self):
        """Lane 0 (DC) passes through butterflies only — no rotators — so
        it must match the exact DCT's DC up to fixed-point rounding."""
        x = np.full(8, 37.0)
        got = t8.loeffler8_fwd(list(x), t8.cordic_rotators())
        assert float(got[0]) == pytest.approx(37.0 * math.sqrt(8), abs=0.1)
        for k in range(1, 8):
            assert abs(float(got[k])) < 0.1


class TestStrip:
    def test_strip_matches_blockwise_matrix(self):
        rng = np.random.default_rng(3)
        strip = rng.standard_normal((8, 40)).astype(np.float32)
        got = np.asarray(t8.transform_strip_matrix(strip))
        d = t8.dct_matrix().astype(np.float32)
        for b in range(5):
            blk = strip[:, b * 8:(b + 1) * 8]
            assert np.allclose(got[:, b * 8:(b + 1) * 8], d @ blk @ d.T,
                               atol=1e-4)

    def test_strip_flow_matches_strip_matrix(self):
        rng = np.random.default_rng(4)
        strip = rng.standard_normal((8, 32)).astype(np.float32)
        a = np.asarray(t8.transform_strip(strip, t8.exact_rotators()))
        b = np.asarray(t8.transform_strip_matrix(strip))
        assert np.allclose(a, b, atol=1e-4)

    @pytest.mark.parametrize("inverse", [False, True])
    def test_strip_matrix_roundtrip(self, inverse):
        rng = np.random.default_rng(5)
        strip = rng.standard_normal((8, 64)).astype(np.float32)
        fwd = np.asarray(t8.transform_strip_matrix(strip, inverse=inverse))
        back = np.asarray(
            t8.transform_strip_matrix(fwd, inverse=not inverse))
        assert np.allclose(back, strip, atol=1e-4)

    def test_strip_flow_roundtrip(self):
        rng = np.random.default_rng(6)
        strip = rng.standard_normal((8, 24)).astype(np.float32)
        rs = t8.exact_rotators()
        back = np.asarray(
            t8.transform_strip(t8.transform_strip(strip, rs), rs,
                               inverse=True))
        assert np.allclose(back, strip, atol=1e-4)
