"""L2: the jitted compute graphs the Rust coordinator executes.

Each public function here is a jax function over statically-shaped f32
arrays, calling the L1 Pallas kernels, and is what ``aot.py`` lowers to HLO
text. Python never runs at serving time — these exist only on the compile
path.

Conventions shared with the Rust side (rust/src/runtime/):

* images are (H, W) f32 row-major, pixel values 0..255 (u8-valued);
* H and W are multiples of 8 (the Rust block manager pads with edge
  replication before submission and crops after);
* every entry point returns a tuple (lowered with return_tuple=True), so
  the Rust side always unwraps a tuple literal.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from .kernels import dct8x8, histeq as histeq_k, psnr as psnr_k


def compress(img, variant: str = "dct", quality: int = 50,
             cordic_iters: int = 3, cordic_frac_bits: int = 10):
    """Full compression pipeline (fused kernel): returns
    ``(reconstructed, quantized_coefficients)``."""
    rec, qc = dct8x8.compress(img, variant=variant, quality=quality,
                              cordic_iters=cordic_iters,
                              cordic_frac_bits=cordic_frac_bits)
    return rec, qc


def compress_unfused(img, variant: str = "dct", quality: int = 50):
    """The paper's §3.2 configuration: DCT, quantizer and IDCT as separate
    kernels (ablation baseline for the fused pipeline)."""
    from .kernels import quantize as quant_k

    x = img.astype(jnp.float32) - 128.0
    coef = dct8x8.dct2d(x, variant=variant)
    qc = quant_k.quantize(coef, quality=quality)
    deq = quant_k.dequantize(qc, quality=quality)
    rec = dct8x8.idct2d(deq, variant=variant)
    return jnp.clip(rec + 128.0, 0.0, 255.0), qc


def dct_only(img, variant: str = "dct"):
    """Forward blockwise DCT of a level-shifted image (microbench entry)."""
    return (dct8x8.dct2d(img.astype(jnp.float32) - 128.0, variant=variant),)


def idct_only(coef, variant: str = "dct"):
    """Inverse blockwise DCT + unshift/clip (microbench entry)."""
    rec = dct8x8.idct2d(coef, variant=variant)
    return (jnp.clip(rec + 128.0, 0.0, 255.0),)


def psnr(a, b):
    """PSNR(a, b) in dB as a (1,)-shaped array (scalar outputs keep the
    tuple-of-arrays convention simple on the Rust side)."""
    return (psnr_k.psnr(a, b).reshape(1),)


def histeq(img):
    """Grayscale histogram equalization (Tables 1-2 caption workload)."""
    return (histeq_k.histeq(img),)


# Entry-point registry used by aot.py: name -> (fn(shape-args), n_inputs).
def entry(kind: str, variant: str = "dct", quality: int = 50):
    """Resolve an artifact kind to a single-signature jax function."""
    if kind == "compress":
        return functools.partial(compress, variant=variant, quality=quality)
    if kind == "compress_unfused":
        return functools.partial(compress_unfused, variant=variant,
                                 quality=quality)
    if kind == "dct":
        return functools.partial(dct_only, variant=variant)
    if kind == "idct":
        return functools.partial(idct_only, variant=variant)
    if kind == "psnr":
        return psnr
    if kind == "histeq":
        return histeq
    raise KeyError(f"unknown artifact kind {kind!r}")
