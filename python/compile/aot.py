"""AOT lowering: jax (L2+L1) -> HLO text artifacts + manifest.json.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --out ../artifacts [--quality 50]
        [--only compress_dct_512x512] [--skip-large]

Produces one ``<name>.hlo.txt`` per artifact plus ``manifest.json``
describing shapes/dtypes/semantics for the Rust runtime loader.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The paper's size sweep (H, W), padded to 8-multiples where needed.
# Table 1 / Figures 5-6 (Lena) + Table 2 / Figures 10-11 (Cable-car).
# 1024x814 pads to 1024x816 (the Rust block manager replicates edges).
LENA_SIZES = [
    (3072, 3072),
    (2048, 2048),
    (1600, 1400),
    (1024, 816),
    (576, 720),
    (512, 512),
    (200, 200),
]
CABLECAR_SIZES = [
    (544, 512),
    (512, 480),
    (448, 416),
    (384, 352),
    (320, 288),
]
ALL_SIZES = sorted(set(LENA_SIZES + CABLECAR_SIZES), reverse=True)

# Shapes above this pixel count are skipped with --skip-large (CI-friendly).
LARGE_PIXELS = 2048 * 2048

VARIANTS = ("dct", "cordic")


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is load-bearing: the default printer elides
    # arrays (the DCT matrix, quantization tables) as literal "{...}" which
    # the 0.5.1 text parser silently turns into garbage values.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_list(quality: int):
    """Yield (name, fn, input_shapes, meta) for every artifact to emit."""
    for (h, w) in ALL_SIZES:
        sz = f"{h}x{w}"
        for variant in VARIANTS:
            yield (
                f"compress_{variant}_{sz}",
                model.entry("compress", variant=variant, quality=quality),
                [(h, w)],
                {"kind": "compress", "variant": variant, "quality": quality,
                 "height": h, "width": w,
                 "outputs": ["recon", "qcoef"]},
            )
        yield (
            f"psnr_{sz}",
            model.entry("psnr"),
            [(h, w), (h, w)],
            {"kind": "psnr", "height": h, "width": w, "outputs": ["psnr_db"]},
        )
        yield (
            f"histeq_{sz}",
            model.entry("histeq"),
            [(h, w)],
            {"kind": "histeq", "height": h, "width": w,
             "outputs": ["equalized"]},
        )
    # Unfused ablation pipeline + bare transforms at one reference size.
    h, w = 512, 512
    for variant in VARIANTS:
        yield (
            f"compress_unfused_{variant}_{h}x{w}",
            model.entry("compress_unfused", variant=variant, quality=quality),
            [(h, w)],
            {"kind": "compress_unfused", "variant": variant,
             "quality": quality, "height": h, "width": w,
             "outputs": ["recon", "qcoef"]},
        )
        yield (
            f"dct_{variant}_{h}x{w}",
            model.entry("dct", variant=variant),
            [(h, w)],
            {"kind": "dct", "variant": variant, "height": h, "width": w,
             "outputs": ["coef"]},
        )


def emit(out_dir: str, quality: int, only=None, skip_large=False,
         verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "quality": quality,
        "dtype": "f32",
        "artifacts": [],
    }
    t_all = time.time()
    for name, fn, in_shapes, meta in artifact_list(quality):
        if only and name not in only:
            continue
        if skip_large and any(h * w > LARGE_PIXELS for (h, w) in in_shapes):
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[_spec(s) for s in in_shapes])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update({
            "name": name,
            "file": fname,
            "inputs": [{"shape": list(s), "dtype": "f32"} for s in in_shapes],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        })
        manifest["artifacts"].append(entry)
        if verbose:
            print(f"  {name:44s} {len(text):>10d} B  {time.time()-t0:5.1f}s",
                  flush=True)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        n = len(manifest["artifacts"])
        print(f"wrote {n} artifacts + manifest.json in "
              f"{time.time()-t_all:.1f}s -> {out_dir}")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quality", type=int, default=50)
    ap.add_argument("--only", action="append", default=None,
                    help="emit only the named artifact(s)")
    ap.add_argument("--skip-large", action="store_true",
                    help=f"skip shapes over {LARGE_PIXELS} pixels")
    args = ap.parse_args(argv)
    emit(args.out, args.quality, only=args.only, skip_large=args.skip_large)
    return 0


if __name__ == "__main__":
    sys.exit(main())
