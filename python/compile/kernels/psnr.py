"""L1 Pallas kernel: PSNR between two images (paper §4.1, eq. 23-24).

The squared-error reduction runs as a Pallas grid over row strips with a
revisited (1, 1) accumulator block — the TPU idiom for cross-grid-step
reductions (initialize on the first step, accumulate on the rest). The final
log10 conversion happens in the surrounding jnp graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PSNR_CAP_DB = 99.0


def _sse_kernel(a_ref, b_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = a_ref[...] - b_ref[...]
    acc_ref[0, 0] += jnp.sum(d * d)


def sse(a, b):
    """Sum of squared differences via the strip-reduction kernel."""
    from .transform8 import pick_strip

    h, w = a.shape
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    if h % 8:
        raise ValueError(f"height {h} not a multiple of 8")
    s = pick_strip(h, w)
    strip = pl.BlockSpec((s, w), lambda i: (i, 0))
    acc = pl.pallas_call(
        _sse_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        grid=(h // s,),
        in_specs=[strip, strip],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return acc[0, 0]


@functools.partial(jax.jit, static_argnames=("max_value",))
def psnr(a, b, max_value: float = 255.0):
    """PSNR in dB; identical images cap at PSNR_CAP_DB (MSE=0 guard)."""
    h, w = a.shape
    m = sse(a, b) / (h * w)
    p = 20.0 * jnp.log10(max_value) - 10.0 * jnp.log10(jnp.maximum(m, 1e-20))
    return jnp.minimum(p, PSNR_CAP_DB)
