"""Pure-jnp reference oracle for every L1 kernel and L2 pipeline.

Everything in this file is deliberately simple, direct code: the ground
truth that pytest checks the Pallas kernels (and, transitively, the Rust
serial baselines — the same tables are burned into ``rust/src/dct/quant.rs``)
against.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import transform8
from .transform8 import RotatorSet, cordic_rotators, dct_matrix, exact_rotators

# ---------------------------------------------------------------------------
# Quantization tables (ITU-T T.81 Annex K, the standard JPEG luma table)
# ---------------------------------------------------------------------------

JPEG_LUMA_Q50 = np.array(
    [
        [16, 11, 10, 16, 24, 40, 51, 61],
        [12, 12, 14, 19, 26, 58, 60, 55],
        [14, 13, 16, 24, 40, 57, 69, 56],
        [14, 17, 22, 29, 51, 87, 80, 62],
        [18, 22, 37, 56, 68, 109, 103, 77],
        [24, 35, 55, 64, 81, 104, 113, 92],
        [49, 64, 78, 87, 103, 121, 120, 101],
        [72, 92, 95, 98, 112, 100, 103, 99],
    ],
    dtype=np.float64,
)


def quality_scale(quality: int) -> float:
    """IJG quality -> table scale factor (percent)."""
    quality = max(1, min(100, int(quality)))
    if quality < 50:
        return 5000.0 / quality
    return 200.0 - 2.0 * quality


def quant_table(quality: int = 50) -> np.ndarray:
    """JPEG luma quantization table at the given IJG quality (1..100)."""
    scale = quality_scale(quality)
    q = np.floor((JPEG_LUMA_Q50 * scale + 50.0) / 100.0)
    return np.clip(q, 1.0, 255.0).astype(np.float32)


# The DCT in this codebase is *orthonormally* scaled (matrix D with rows of
# unit norm), while the JPEG tables are designed for the conventional JPEG
# DCT scaling in which each 2-D coefficient is 4x the orthonormal one for
# N=8. We fold that factor into the table so quantization behaves like a
# standard JPEG codec at the same nominal quality.
JPEG_DCT_GAIN = 4.0


def effective_qtable(quality: int = 50) -> np.ndarray:
    return (quant_table(quality) / JPEG_DCT_GAIN).astype(np.float32)


# ---------------------------------------------------------------------------
# Blockwise 2-D DCT (exact, matrix form) over a whole image
# ---------------------------------------------------------------------------

def _to_blocks(img):
    """(H, W) -> (H//8, W//8, 8, 8)"""
    h, w = img.shape
    return img.reshape(h // 8, 8, w // 8, 8).transpose(0, 2, 1, 3)


def _from_blocks(blk):
    nbh, nbw, _, _ = blk.shape
    return blk.transpose(0, 2, 1, 3).reshape(nbh * 8, nbw * 8)


def dct2d_blocks(img):
    """Exact orthonormal blockwise 2-D DCT of an (H, W) image."""
    d = jnp.asarray(dct_matrix(np.float32))
    blk = _to_blocks(img)
    return _from_blocks(jnp.einsum("ij,bcjk,lk->bcil", d, blk, d))


def idct2d_blocks(coef):
    d = jnp.asarray(dct_matrix(np.float32))
    blk = _to_blocks(coef)
    return _from_blocks(jnp.einsum("ji,bcjk,kl->bcil", d, blk, d))


def loeffler2d_blocks(img, rs: RotatorSet, inverse: bool = False):
    """Blockwise 2-D transform via the (Cordic-)Loeffler strip routine —
    oracle for the Cordic variant kernels (same arithmetic, applied strip by
    strip in plain python)."""
    h, _w = img.shape
    strips = [
        transform8.transform_strip(img[i * 8:(i + 1) * 8, :], rs, inverse=inverse)
        for i in range(h // 8)
    ]
    return jnp.concatenate(strips, axis=0)


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quantize(coef, q):
    """Round(coef / q) with q tiled over the image."""
    h, w = coef.shape
    qt = jnp.tile(jnp.asarray(q), (h // 8, w // 8))
    return jnp.round(coef / qt)


def dequantize(qcoef, q):
    h, w = qcoef.shape
    qt = jnp.tile(jnp.asarray(q), (h // 8, w // 8))
    return qcoef * qt


# ---------------------------------------------------------------------------
# Full compression pipeline (the paper's workload)
# ---------------------------------------------------------------------------

def compress_pipeline(img, quality: int = 50, variant: str = "dct",
                      cordic_iters: int = 3, cordic_frac_bits: int = 10
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Level shift -> blockwise DCT -> quantize -> dequantize -> standard
    IDCT -> unshift -> clip. Returns ``(reconstructed, quantized_coeffs)``.

    ``variant`` selects the *forward* transform: ``'dct'`` (exact, matrix),
    ``'cordic'`` (Cordic-based Loeffler, fixed-point rotators) or
    ``'loeffler'`` (flow graph with exact rotators). The decoder side is
    always the standard IDCT — the deployment the paper's PSNR tables
    describe: a low-power approximate-DCT encoder feeding a standards-
    compliant decoder, so the encoder's approximation error is *not*
    cancelled and shows up as the ~2 dB Table 3-4 gap.
    """
    q = effective_qtable(quality)
    x = img.astype(jnp.float32) - 128.0
    if variant == "dct":
        coef = dct2d_blocks(x)
    elif variant == "cordic":
        rs = cordic_rotators(cordic_iters, cordic_frac_bits)
        coef = loeffler2d_blocks(x, rs)
    elif variant == "loeffler":
        coef = loeffler2d_blocks(x, exact_rotators())
    else:
        raise ValueError(f"unknown variant {variant!r}")
    qc = quantize(coef, q)
    deq = dequantize(qc, q)
    rec = idct2d_blocks(deq)
    rec = jnp.clip(rec + 128.0, 0.0, 255.0)
    return rec, qc


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

PSNR_CAP_DB = 99.0


def mse(a, b):
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.mean(d * d)


def psnr(a, b, max_value: float = 255.0):
    """Paper eq. (23)/(24). Identical images are capped at PSNR_CAP_DB."""
    m = mse(a, b)
    p = 20.0 * jnp.log10(max_value) - 10.0 * jnp.log10(jnp.maximum(m, 1e-20))
    return jnp.minimum(p, PSNR_CAP_DB)


# ---------------------------------------------------------------------------
# Histogram equalization (paper Tables 1-2 caption workload)
# ---------------------------------------------------------------------------

def histogram256(img):
    """256-bin histogram of a u8-valued (but f32-typed) image."""
    idx = jnp.clip(img, 0.0, 255.0).astype(jnp.int32)
    return jnp.zeros((256,), jnp.float32).at[idx.reshape(-1)].add(1.0)


def histeq_lut(hist, npix: int):
    """Classic histogram-equalization LUT: scaled cumulative distribution,
    using the 'first occupied bin' normalization so the darkest occupied
    level maps to 0."""
    cdf = jnp.cumsum(hist)
    cdf_min = cdf[jnp.argmax(hist > 0)]
    denom = jnp.maximum(float(npix) - cdf_min, 1.0)
    lut = jnp.round((cdf - cdf_min) / denom * 255.0)
    return jnp.clip(lut, 0.0, 255.0)


def histeq(img):
    h, w = img.shape
    hist = histogram256(img)
    lut = histeq_lut(hist, h * w)
    idx = jnp.clip(img, 0.0, 255.0).astype(jnp.int32)
    return lut[idx]
