"""8-point 1-D DCT transforms: exact matrix, Loeffler flow graph, and the
Cordic-based Loeffler variant of Sun/Heyne/Ruan/Goetze (2006) — the
algorithm the paper evaluates.

All transform functions here operate on a *list of 8 arrays* (the 8 lanes of
the flow graph) so the same code vectorizes over any trailing shape. They are
written in pure jnp so they can be used both

  * inside Pallas kernels (L1) — lowered with interpret=True into the same
    HLO module as the surrounding L2 graph, and
  * in the pure-jnp reference oracle (ref.py) that pytest checks kernels
    against.

Flow graph (verified numerically against the orthonormal DCT-II matrix to
<1e-12, see tests/test_transform8.py)::

    stage 1: butterflies  x0..x7 -> a0..a7
    stage 2: even butterflies (a0..a3 -> b0..b3)
             odd rotators   rot(3pi/16) on (a4,a7), rot(pi/16) on (a5,a6)
    stage 3: even: X0/X4 butterfly, sqrt2*rot(6pi/16) on (b2,b3)
             odd:  butterflies -> c4..c7
    stage 4: X1=c4+c7, X7=c7-c4, X3=sqrt2*c5, X5=sqrt2*c6
    scale:   /sqrt(8)  (orthonormal normalization)

The Cordic variant replaces each plane rotation with a short sequence of
CORDIC micro-rotations (shift-add in hardware) evaluated in simulated
fixed-point: every intermediate is rounded to `frac_bits` fractional bits,
exactly as a shift-add datapath truncates. This injects the real
approximation loss the paper's Tables 3-4 measure (Cordic-Loeffler PSNR a
couple of dB under the exact DCT).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

SQRT2 = math.sqrt(2.0)
INV_SQRT8 = 1.0 / math.sqrt(8.0)

# Rotator angles of the Loeffler graph (radians).
ANGLE_ODD_A = 3.0 * math.pi / 16.0  # rotator "c3" on (a4, a7)
ANGLE_ODD_B = 1.0 * math.pi / 16.0  # rotator "c1" on (a5, a6)
ANGLE_EVEN = 6.0 * math.pi / 16.0   # rotator "sqrt2*c6" on (b2, b3)


def dct_matrix(dtype=np.float64) -> np.ndarray:
    """Orthonormal 8-point DCT-II matrix D, so that y = D @ x."""
    d = np.zeros((8, 8), dtype=np.float64)
    for k in range(8):
        ck = math.sqrt(0.5) if k == 0 else 1.0
        for n in range(8):
            d[k, n] = 0.5 * ck * math.cos((2 * n + 1) * k * math.pi / 16.0)
    return d.astype(dtype)


# ---------------------------------------------------------------------------
# CORDIC planning (host-side, produces compile-time constants)
# ---------------------------------------------------------------------------

def cordic_plan(theta: float, iters: int) -> Tuple[List[int], float, float]:
    """Greedy CORDIC micro-rotation plan for clockwise rotation by ``theta``.

    Returns ``(sigmas, achieved_angle, gain)`` where ``sigmas[i]`` is the
    direction of micro-rotation ``i`` (angle atan(2^-i)), ``achieved_angle``
    is the accumulated angle and ``gain`` is the CORDIC magnitude gain
    ``prod(sqrt(1 + 4^-i))`` that a hardware implementation folds into the
    quantization stage.
    """
    sigmas: List[int] = []
    phi = 0.0
    gain = 1.0
    for i in range(iters):
        sigma = 1 if phi < theta else -1
        sigmas.append(sigma)
        phi += sigma * math.atan(2.0 ** (-i))
        gain *= math.sqrt(1.0 + 4.0 ** (-i))
    return sigmas, phi, gain


@dataclass(frozen=True)
class Rotator:
    """A plane rotation in the Loeffler graph.

    ``mode='exact'`` applies the ideal rotation with float multiplies;
    ``mode='cordic'`` applies ``iters`` CORDIC micro-rotations with every
    intermediate rounded to ``frac_bits`` fractional bits (fixed-point
    hardware simulation). ``scale`` is an extra output gain (sqrt(2) for the
    even rotator of the graph).
    """

    theta: float
    scale: float = 1.0
    mode: str = "exact"          # 'exact' | 'cordic'
    iters: int = 4
    frac_bits: Optional[int] = None

    def plan(self) -> Tuple[List[int], float, float]:
        return cordic_plan(self.theta, self.iters)


def _fxp(v, frac_bits: Optional[int]):
    """Round ``v`` to ``frac_bits`` fractional bits (fixed-point truncation
    model). No-op when frac_bits is None."""
    if frac_bits is None:
        return v
    s = float(1 << frac_bits)
    return jnp.round(v * s) * (1.0 / s)


def rotate_cw(x, y, rot: Rotator):
    """Apply the graph's rotation convention to lanes (x, y)::

        x' = scale * ( x*cos(theta) + y*sin(theta) )
        y' = scale * (-x*sin(theta) + y*cos(theta) )

    i.e. the matrix [[c, s], [-s, c]] (clockwise in the standard
    orientation), optionally via fixed-point CORDIC micro-rotations.
    """
    if rot.mode == "exact":
        c = math.cos(rot.theta) * rot.scale
        s = math.sin(rot.theta) * rot.scale
        return x * c + y * s, -x * s + y * c
    if rot.mode != "cordic":
        raise ValueError(f"unknown rotator mode {rot.mode!r}")

    sigmas, _phi, gain = rot.plan()
    fb = rot.frac_bits
    x = _fxp(x, fb)
    y = _fxp(y, fb)
    for i, sigma in enumerate(sigmas):
        shift = 2.0 ** (-i)
        # Clockwise micro-rotation: accumulated matrix converges to
        # [[cos, sin], [-sin, cos]] of the achieved angle, scaled by `gain`.
        xn = x + sigma * y * shift
        yn = y - sigma * x * shift
        x = _fxp(xn, fb)
        y = _fxp(yn, fb)
    # Gain compensation (hardware folds this into the quantizer; we model it
    # as one more rounded constant multiply).
    comp = rot.scale / gain
    return _fxp(x * comp, fb), _fxp(y * comp, fb)


def rotate_ccw(x, y, rot: Rotator):
    """Inverse of :func:`rotate_cw` up to the rotator's own approximation
    error: rotation by -theta with matching scale handling (1/scale)."""
    if rot.mode == "exact":
        c = math.cos(rot.theta) / rot.scale
        s = math.sin(rot.theta) / rot.scale
        return x * c - y * s, x * s + y * c
    sigmas, _phi, gain = rot.plan()
    fb = rot.frac_bits
    x = _fxp(x, fb)
    y = _fxp(y, fb)
    for i, sigma in enumerate(sigmas):
        shift = 2.0 ** (-i)
        xn = x - sigma * y * shift
        yn = y + sigma * x * shift
        x = _fxp(xn, fb)
        y = _fxp(yn, fb)
    comp = 1.0 / (rot.scale * gain)
    return _fxp(x * comp, fb), _fxp(y * comp, fb)


@dataclass(frozen=True)
class RotatorSet:
    """The three rotators of the Loeffler graph plus the scalar constants,
    configured either exactly or as fixed-point CORDIC."""

    odd_a: Rotator  # 3pi/16 on (a4, a7)
    odd_b: Rotator  # pi/16 on (a5, a6)
    even: Rotator   # 6pi/16 with sqrt(2) gain on (b2, b3)
    frac_bits: Optional[int] = None

    def const(self, v: float):
        """A scalar constant, rounded to the set's fixed-point grid."""
        if self.frac_bits is None:
            return v
        s = float(1 << self.frac_bits)
        return round(v * s) / s


def exact_rotators() -> RotatorSet:
    return RotatorSet(
        odd_a=Rotator(ANGLE_ODD_A),
        odd_b=Rotator(ANGLE_ODD_B),
        even=Rotator(ANGLE_EVEN, scale=SQRT2),
    )


def cordic_rotators(iters: int = 3, frac_bits: int = 10) -> RotatorSet:
    """Rotator set for the Cordic-based Loeffler DCT (paper Fig. 1).

    Defaults (3 micro-rotations, 10 fractional bits) are calibrated so the
    pipeline PSNR lands ~2 dB under the exact DCT when decoded with a
    standard IDCT, matching the gap in the paper's Tables 3-4.
    """
    return RotatorSet(
        odd_a=Rotator(ANGLE_ODD_A, mode="cordic", iters=iters, frac_bits=frac_bits),
        odd_b=Rotator(ANGLE_ODD_B, mode="cordic", iters=iters, frac_bits=frac_bits),
        even=Rotator(ANGLE_EVEN, scale=SQRT2, mode="cordic", iters=iters,
                     frac_bits=frac_bits),
        frac_bits=frac_bits,
    )


# ---------------------------------------------------------------------------
# Loeffler forward / inverse flow graphs
# ---------------------------------------------------------------------------

def loeffler8_fwd(xs: Sequence, rs: RotatorSet) -> List:
    """Forward 8-point DCT-II via the (Cordic-based) Loeffler flow graph.

    ``xs`` is a sequence of 8 arrays (lane values); returns the 8 transform
    lanes in natural frequency order, orthonormally scaled.
    """
    x0, x1, x2, x3, x4, x5, x6, x7 = xs
    # stage 1
    a0 = x0 + x7
    a1 = x1 + x6
    a2 = x2 + x5
    a3 = x3 + x4
    a7 = x0 - x7
    a6 = x1 - x6
    a5 = x2 - x5
    a4 = x3 - x4
    # stage 2 even
    b0 = a0 + a3
    b1 = a1 + a2
    b3 = a0 - a3
    b2 = a1 - a2
    # stage 2 odd rotators
    b4, b7 = rotate_cw(a4, a7, rs.odd_a)
    b5, b6 = rotate_cw(a5, a6, rs.odd_b)
    # stage 3 even
    X0 = b0 + b1
    X4 = b0 - b1
    X2, X6 = rotate_cw(b2, b3, rs.even)
    # stage 3 odd
    c4 = b4 + b6
    c6 = b4 - b6
    c7 = b7 + b5
    c5 = b7 - b5
    # stage 4
    X1 = c4 + c7
    X7 = c7 - c4
    r2 = rs.const(SQRT2)
    X3 = c5 * r2
    X5 = c6 * r2
    n = rs.const(INV_SQRT8)
    return [v * n for v in (X0, X1, X2, X3, X4, X5, X6, X7)]


def loeffler8_inv(ys: Sequence, rs: RotatorSet) -> List:
    """Inverse of :func:`loeffler8_fwd`: the transposed flow graph with each
    stage inverted. For exact rotators this is the exact orthonormal inverse;
    for CORDIC rotators the fixed-point rounding does not cancel, which is
    precisely the reconstruction loss the paper's PSNR tables measure."""
    s8 = rs.const(math.sqrt(8.0))
    X0, X1, X2, X3, X4, X5, X6, X7 = [v * s8 for v in ys]
    # stage 4 inverse
    c4 = (X1 - X7) * 0.5
    c7 = (X1 + X7) * 0.5
    ir2 = rs.const(1.0 / SQRT2)
    c5 = X3 * ir2
    c6 = X5 * ir2
    # stage 3 odd inverse
    b4 = (c4 + c6) * 0.5
    b6 = (c4 - c6) * 0.5
    b7 = (c7 + c5) * 0.5
    b5 = (c7 - c5) * 0.5
    # stage 3 even inverse
    b0 = (X0 + X4) * 0.5
    b1 = (X0 - X4) * 0.5
    b2, b3 = rotate_ccw(X2, X6, rs.even)
    # stage 2 odd inverse
    a4, a7 = rotate_ccw(b4, b7, rs.odd_a)
    a5, a6 = rotate_ccw(b5, b6, rs.odd_b)
    # stage 2 even inverse
    a0 = (b0 + b3) * 0.5
    a3 = (b0 - b3) * 0.5
    a1 = (b1 + b2) * 0.5
    a2 = (b1 - b2) * 0.5
    # stage 1 inverse
    x0 = (a0 + a7) * 0.5
    x7 = (a0 - a7) * 0.5
    x1 = (a1 + a6) * 0.5
    x6 = (a1 - a6) * 0.5
    x2 = (a2 + a5) * 0.5
    x5 = (a2 - a5) * 0.5
    x3 = (a3 + a4) * 0.5
    x4 = (a3 - a4) * 0.5
    return [x0, x1, x2, x3, x4, x5, x6, x7]


# ---------------------------------------------------------------------------
# Strip-level application (shared by kernels and oracle)
# ---------------------------------------------------------------------------

# VMEM budget per staged strip buffer (bytes). Governs the strip-height
# choice: strips are the Pallas grid unit (the CUDA-threadblock analogue),
# and taller strips amortize per-grid-step overhead — the single biggest
# performance lever of the §Perf pass (see EXPERIMENTS.md).
STRIP_BYTES_CAP = 2 * 1024 * 1024


def pick_strip(h: int, w: int, cap_bytes: int = STRIP_BYTES_CAP) -> int:
    """Largest strip height that (a) divides ``h``, (b) is a multiple of 8,
    and (c) keeps one f32 strip buffer under ``cap_bytes`` of VMEM."""
    limit = max(8, cap_bytes // (w * 4))
    best = 8
    s = 8
    while s <= min(h, limit):
        if h % s == 0:
            best = s
        s += 8
    return best


def transform_strip(strip, rs: RotatorSet, inverse: bool = False):
    """Apply the 8x8 blockwise 2-D transform to an ``(S, W)`` strip of
    blocks (S, W multiples of 8).

    Vertical pass: the 8-point transform down each in-block column, with
    the lanes being the 8 rows of each block-row group (vectorized over
    groups x columns). Horizontal pass: the 8-point transform along each
    block row (lanes are the 8 in-block columns, vectorized over rows x
    blocks).
    """
    f = loeffler8_inv if inverse else loeffler8_fwd
    s, w = strip.shape
    g = s // 8
    nb = w // 8

    def vertical(x):
        t = x.reshape(g, 8, w)
        lanes = f([t[:, i, :] for i in range(8)], rs)
        return jnp.stack(lanes, axis=1).reshape(s, w)

    def horizontal(x):
        t = x.reshape(s, nb, 8)
        lanes = f([t[:, :, j] for j in range(8)], rs)
        return jnp.stack(lanes, axis=-1).reshape(s, w)

    if inverse:
        # undo the horizontal pass first so fwd/inv compose per-pass
        return vertical(horizontal(strip))
    return horizontal(vertical(strip))


def transform_strip_matrix(strip, d=None, inverse: bool = False):
    """Exact 2-D transform on an ``(S, W)`` strip via the DCT matrix — the
    MXU-friendly formulation used by the exact-DCT Pallas kernel (8x8
    matmuls per block, batched as einsums over the whole strip). ``d`` is
    the 8x8 DCT matrix; inside Pallas kernels it must be passed in as a
    kernel input (Pallas forbids captured array constants)."""
    if d is None:
        d = jnp.asarray(dct_matrix(np.float32))
    s, w = strip.shape
    g = s // 8
    nb = w // 8
    t = strip.reshape(g, 8, w)
    if inverse:
        # vertical inverse: D^T @ rows ; horizontal inverse: blocks @ D
        v = jnp.einsum("ji,gjw->giw", d, t).reshape(s, nb, 8)
        o = jnp.einsum("rbk,kc->rbc", v, d)
        return o.reshape(s, w)
    v = jnp.einsum("ij,gjw->giw", d, t).reshape(s, nb, 8)
    o = jnp.einsum("rbk,ck->rbc", v, d)
    return o.reshape(s, w)
