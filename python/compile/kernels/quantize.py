"""L1 Pallas kernels: standalone quantize / dequantize over row strips.

The paper runs "the DCT, the quantizer and the IDCT ... on different
kernels" (§3.2); these are the quantizer kernels for that unfused
configuration (the fused single-pass kernel lives in dct8x8.compress and is
what the optimized pipeline uses — the ablation bench compares both).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .transform8 import pick_strip


def _quant_kernel(x_ref, q_ref, o_ref, *, dequant: bool):
    strip = x_ref[...]
    qt = jnp.tile(q_ref[...], (strip.shape[0] // 8, strip.shape[1] // 8))
    if dequant:
        o_ref[...] = strip * qt
    else:
        o_ref[...] = jnp.round(strip / qt)


def _call(coef, qtable, dequant: bool):
    h, w = coef.shape
    if h % 8 or w % 8:
        raise ValueError(f"shape {coef.shape} not a multiple of 8")
    kern = functools.partial(_quant_kernel, dequant=dequant)
    strip = pick_strip(h, w)
    spec = pl.BlockSpec((strip, w), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        grid=(h // strip,),
        in_specs=[spec, pl.BlockSpec((8, 8), lambda i: (0, 0))],
        out_specs=spec,
        interpret=True,
    )(coef.astype(jnp.float32), jnp.asarray(qtable))


@functools.partial(jax.jit, static_argnames=("quality",))
def quantize(coef, quality: int = 50):
    """Round(coef / Q) blockwise, Q = JPEG luma table at ``quality`` scaled
    for the orthonormal DCT."""
    from . import ref

    return _call(coef, ref.effective_qtable(quality), dequant=False)


@functools.partial(jax.jit, static_argnames=("quality",))
def dequantize(qcoef, quality: int = 50):
    """qcoef * Q blockwise — inverse of :func:`quantize` up to rounding."""
    from . import ref

    return _call(qcoef, ref.effective_qtable(quality), dequant=True)
