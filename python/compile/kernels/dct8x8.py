"""L1 Pallas kernels: blockwise 8x8 DCT / IDCT and the fused compression
kernel over row strips.

Hardware mapping (DESIGN.md §Hardware-Adaptation): the paper launches one
CUDA threadblock per image tile with the tile staged in ``__shared__``
memory. On the TPU programming model that is a Pallas grid with one step per
(8, W) strip of horizontally-adjacent 8x8 blocks, the strip staged in VMEM
by the BlockSpec, and the exact-DCT variant phrased as 8x8 matmuls so the
MXU does the work. Kernels are lowered ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls) — correctness is validated through this path
and real-TPU perf is estimated from the VMEM/MXU model in DESIGN.md.

Array-valued compile-time tables (the 8x8 DCT matrix, the quantization
table) are passed as kernel *inputs* with a constant index_map — Pallas
forbids captured array constants — so they stay VMEM-resident across grid
steps.

Strip height is chosen per shape by ``transform8.pick_strip``: the tallest
divisor of H (multiple of 8) whose f32 strip buffer stays under a 2 MiB
VMEM cap — 3-4 live buffers plus lane temporaries stay comfortably inside
the ~16 MiB/core VMEM with room for double buffering, while grid-step
count (and with it per-step dispatch overhead, the dominant cost of the
original 8-row strips — see EXPERIMENTS.md §Perf) drops by up to 16x.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .transform8 import (
    RotatorSet,
    cordic_rotators,
    dct_matrix,
    exact_rotators,
    pick_strip,
    transform_strip,
    transform_strip_matrix,
)


def _strip_spec(strip: int, w: int):
    return pl.BlockSpec((strip, w), lambda i: (i, 0))


def _const_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i: (0,) * nd)


def _rotators(variant: str, iters: int, frac_bits: int) -> RotatorSet:
    if variant == "cordic":
        return cordic_rotators(iters, frac_bits)
    if variant == "loeffler":
        return exact_rotators()
    raise ValueError(f"unknown variant {variant!r}")


# ---------------------------------------------------------------------------
# Bare (I)DCT kernels
# ---------------------------------------------------------------------------

def _dct_matrix_kernel(x_ref, d_ref, o_ref, *, inverse: bool):
    o_ref[...] = transform_strip_matrix(x_ref[...], d_ref[...],
                                        inverse=inverse)


def _dct_flow_kernel(x_ref, o_ref, *, rs: RotatorSet, inverse: bool):
    o_ref[...] = transform_strip(x_ref[...], rs, inverse=inverse)


@functools.partial(jax.jit, static_argnames=("variant", "inverse",
                                             "cordic_iters",
                                             "cordic_frac_bits"))
def dct2d(img, variant: str = "dct", inverse: bool = False,
          cordic_iters: int = 3, cordic_frac_bits: int = 10):
    """Blockwise 2-D (I)DCT of an (H, W) f32 image, H and W multiples of 8.

    ``variant``: 'dct' (exact, MXU matmul), 'loeffler' (flow graph, exact
    rotators), 'cordic' (Cordic-based Loeffler, fixed-point rotators).
    """
    h, w = img.shape
    if h % 8 or w % 8:
        raise ValueError(f"image shape {img.shape} not a multiple of 8")
    img = img.astype(jnp.float32)
    strip = pick_strip(h, w)
    if variant == "dct":
        d = jnp.asarray(dct_matrix(np.float32))
        return pl.pallas_call(
            functools.partial(_dct_matrix_kernel, inverse=inverse),
            out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
            grid=(h // strip,),
            in_specs=[_strip_spec(strip, w), _const_spec((8, 8))],
            out_specs=_strip_spec(strip, w),
            interpret=True,
        )(img, d)
    rs = _rotators(variant, cordic_iters, cordic_frac_bits)
    return pl.pallas_call(
        functools.partial(_dct_flow_kernel, rs=rs, inverse=inverse),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        grid=(h // strip,),
        in_specs=[_strip_spec(strip, w)],
        out_specs=_strip_spec(strip, w),
        interpret=True,
    )(img)


def idct2d(coef, variant: str = "dct", **kw):
    return dct2d(coef, variant=variant, inverse=True, **kw)


# ---------------------------------------------------------------------------
# Fused compression kernel: one VMEM-resident pass per strip doing
# level-shift -> DCT -> quantize -> dequantize -> IDCT -> unshift+clip,
# emitting both the reconstruction and the quantized coefficients (the
# entropy coder input for the Rust codec).
# ---------------------------------------------------------------------------

def _compress_matrix_kernel(x_ref, d_ref, q_ref, rec_ref, qc_ref):
    strip = x_ref[...] - 128.0
    d = d_ref[...]
    qt = jnp.tile(q_ref[...], (strip.shape[0] // 8, strip.shape[1] // 8))
    coef = transform_strip_matrix(strip, d)
    qc = jnp.round(coef / qt)
    deq = qc * qt
    rec = transform_strip_matrix(deq, d, inverse=True)
    rec_ref[...] = jnp.clip(rec + 128.0, 0.0, 255.0)
    qc_ref[...] = qc


def _compress_flow_kernel(x_ref, d_ref, q_ref, rec_ref, qc_ref,
                          *, rs: RotatorSet):
    # Forward: approximate (Cordic-)Loeffler encoder hardware.
    # Decode: standard matrix IDCT (a standards-compliant decoder), so the
    # encoder's approximation error is measured, not cancelled — the
    # deployment behind the paper's Table 3-4 PSNR gap.
    strip = x_ref[...] - 128.0
    qt = jnp.tile(q_ref[...], (strip.shape[0] // 8, strip.shape[1] // 8))
    coef = transform_strip(strip, rs)
    qc = jnp.round(coef / qt)
    deq = qc * qt
    rec = transform_strip_matrix(deq, d_ref[...], inverse=True)
    rec_ref[...] = jnp.clip(rec + 128.0, 0.0, 255.0)
    qc_ref[...] = qc


@functools.partial(jax.jit, static_argnames=("variant", "quality",
                                             "cordic_iters",
                                             "cordic_frac_bits"))
def compress(img, variant: str = "dct", quality: int = 50,
             cordic_iters: int = 3, cordic_frac_bits: int = 10
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused full-pipeline compression of an (H, W) f32 image.

    Returns ``(reconstructed, quantized_coefficients)``, both (H, W) f32.
    The quantization table (JPEG luma at ``quality``, orthonormal-DCT
    scaled) is a compile-time constant of the artifact, matching the AOT
    model: one executable per (shape, variant, quality).
    """
    from . import ref  # local import: ref depends only on transform8

    h, w = img.shape
    if h % 8 or w % 8:
        raise ValueError(f"image shape {img.shape} not a multiple of 8")
    img = img.astype(jnp.float32)
    qtable = jnp.asarray(ref.effective_qtable(quality))
    out_shape = (
        jax.ShapeDtypeStruct((h, w), jnp.float32),
        jax.ShapeDtypeStruct((h, w), jnp.float32),
    )
    strip = pick_strip(h, w)
    if variant == "dct":
        d = jnp.asarray(dct_matrix(np.float32))
        return pl.pallas_call(
            _compress_matrix_kernel,
            out_shape=out_shape,
            grid=(h // strip,),
            in_specs=[_strip_spec(strip, w), _const_spec((8, 8)),
                      _const_spec((8, 8))],
            out_specs=(_strip_spec(strip, w), _strip_spec(strip, w)),
            interpret=True,
        )(img, d, qtable)
    rs = _rotators(variant, cordic_iters, cordic_frac_bits)
    d = jnp.asarray(dct_matrix(np.float32))
    return pl.pallas_call(
        functools.partial(_compress_flow_kernel, rs=rs),
        out_shape=out_shape,
        grid=(h // strip,),
        in_specs=[_strip_spec(strip, w), _const_spec((8, 8)),
                  _const_spec((8, 8))],
        out_specs=(_strip_spec(strip, w), _strip_spec(strip, w)),
        interpret=True,
    )(img, d, qtable)
