"""L1 Pallas kernels: grayscale histogram equalization.

Tables 1-2 of the paper are captioned "time comparisons of grayscale
histogram/equalization", so the equalization pipeline is reproduced as its
own pair of kernels alongside the DCT pipeline:

  1. ``_hist_kernel``  — 256-bin histogram, a strip-grid reduction into a
     revisited (1, 256) accumulator via scatter-add. (On a real TPU one
     would chunk the strip and use the one-hot-matmul trick to put the
     accumulation on the MXU; the interpret/CPU path scatter-adds, which
     lowers to the same HLO scatter the CPU backend runs well.)
  2. ``_apply_kernel`` — LUT application per strip (gather).

The CDF -> LUT conversion between the two is a 256-element jnp graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .transform8 import pick_strip

BINS = 256


def _hist_kernel(x_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    px = jnp.clip(x_ref[...], 0.0, 255.0).reshape(-1).astype(jnp.int32)
    ones = jnp.ones_like(px, dtype=jnp.float32)
    acc_ref[...] += (
        jnp.zeros((BINS,), jnp.float32).at[px].add(ones).reshape(1, BINS)
    )


def histogram256(img):
    """256-bin histogram of a u8-valued (f32-typed) (H, W) image."""
    h, w = img.shape
    if h % 8:
        raise ValueError(f"height {h} not a multiple of 8")
    s = pick_strip(h, w)
    acc = pl.pallas_call(
        _hist_kernel,
        out_shape=jax.ShapeDtypeStruct((1, BINS), jnp.float32),
        grid=(h // s,),
        in_specs=[pl.BlockSpec((s, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, BINS), lambda i: (0, 0)),
        interpret=True,
    )(img.astype(jnp.float32))
    return acc[0]


def _apply_kernel(x_ref, lut_ref, o_ref):
    idx = jnp.clip(x_ref[...], 0.0, 255.0).astype(jnp.int32)
    o_ref[...] = lut_ref[0][idx]


def apply_lut(img, lut):
    h, w = img.shape
    s = pick_strip(h, w)
    return pl.pallas_call(
        _apply_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        grid=(h // s,),
        in_specs=[
            pl.BlockSpec((s, w), lambda i: (i, 0)),
            pl.BlockSpec((1, BINS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((s, w), lambda i: (i, 0)),
        interpret=True,
    )(img.astype(jnp.float32), lut.reshape(1, BINS).astype(jnp.float32))


@jax.jit
def histeq(img):
    """Full histogram equalization of an (H, W) u8-valued f32 image."""
    h, w = img.shape
    hist = histogram256(img)
    cdf = jnp.cumsum(hist)
    cdf_min = cdf[jnp.argmax(hist > 0)]
    denom = jnp.maximum(float(h * w) - cdf_min, 1.0)
    lut = jnp.clip(jnp.round((cdf - cdf_min) / denom * 255.0), 0.0, 255.0)
    return apply_lut(img, lut)
